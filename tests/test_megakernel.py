"""Persistent wave-replay megakernel (ISSUE 3): fp32-tolerance parity
with the interpreted tile walk on every AlexNet 128 KB plan, one
pallas_call per layer (dispatch counting), KernelProgram lowering
invariants on randomized geometries/budgets, chain coarsening, VMEM
re-planning, fused bias+ReLU+pool epilogue, and session serving with
donated input buffers."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.decomposition import (ALEXNET_STACK, ConvLayer, evaluate,
                                      plan_decomposition)
from repro.core.schedule import (KERNEL_OP_COLS, OP_C0, OP_VC, OP_VR,
                                 KernelProgram, compile_layer,
                                 compile_network, lower_kernel_program,
                                 partition_waves, validate_kernel_program)
from repro.core.streaming import (conv2d_direct, maxpool_direct,
                                  network_forward_fn, network_operands,
                                  plan_for_vmem, run_layer_interpreted,
                                  run_layer_megakernel, run_layer_streamed)
from repro.kernels.wave_replay import (expand_grouped, launch_count,
                                       reset_launch_count,
                                       wave_replay_layer, wave_replay_ref)
from repro.launch.session import StreamingSession

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # dev-only dependency (requirements.txt)
    hypothesis = None


def _weights(layer, key=1, scale=0.1):
    l = layer
    k1, k2 = jax.random.split(jax.random.key(key))
    w = jax.random.normal(
        k1, (l.kernel, l.kernel, l.in_c // l.groups, l.out_c)) * scale
    b = jax.random.normal(k2, (l.out_c,)) * scale
    return w, b


def _wave(layer, plan):
    return partition_waves(compile_layer(layer, plan))


# ---------------------------------------------------------------------------
# Acceptance gate: fp32-tolerance parity on every AlexNet 128 KB plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layer", ALEXNET_STACK, ids=lambda l: l.name)
def test_megakernel_matches_interpreter_alexnet(layer):
    """Every ALEXNET_STACK layer under its own 128 KB plan — grouped
    conv2/4/5 (natural per-group gemms) and conv3's in_splits=256
    partial-sum chain included. The megakernel's im2col matmuls may
    round differently from the XLA conv by a few ULP, hence tolerance
    rather than bit-equality (the ISSUE 3 acceptance gate)."""
    l = layer
    plan = plan_decomposition(l, 128 * 1024)
    x = jax.random.normal(jax.random.key(0), (2, l.in_h, l.in_w, l.in_c))
    w, b = _weights(l, scale=0.05)
    mega = run_layer_streamed(l, plan, x, w, b, mode="megakernel")
    interp = run_layer_interpreted(l, plan, x, w, b)
    scale = float(jnp.max(jnp.abs(interp))) + 1e-6
    assert float(jnp.max(jnp.abs(mega - interp))) / scale < 1e-5


@pytest.mark.parametrize("vmem_kib", [64, 256, None])
def test_megakernel_chain_coarsening_levels(vmem_kib):
    """A deep partial-sum chain replayed 1:1 (``vmem_budget=None``) and
    coarsened under two budget points — all three within fp32 tolerance
    of the interpreter, exercising multi-step VMEM accumulation."""
    layer = ConvLayer("chain", 13, 13, 64, 32, 3, pad=1)
    plan = evaluate(layer, 2, 2, 1, 16)       # 16-wave chain, 4 tiles
    assert plan is not None
    wprog = _wave(layer, plan)
    budget = vmem_kib * 1024 if vmem_kib else None
    kp = lower_kernel_program(wprog, vmem_budget=budget)
    if vmem_kib is None:
        assert kp.chain_chunk == 1 and kp.n_chain == 16
    x = jax.random.normal(jax.random.key(1), (1, 13, 13, 64))
    w, b = _weights(layer)
    got = run_layer_megakernel(wprog, x, w, b, vmem_budget=budget)
    ref = run_layer_interpreted(layer, plan, x, w, b)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_megakernel_fused_epilogue_relu_pool():
    """bias+ReLU+overlapping max-pool on the last chain step, per tile,
    entirely in VMEM — against the direct conv+pool oracle."""
    layer = ConvLayer("ep", 20, 20, 8, 16, 3, pad=1, pool=3, pool_stride=2)
    plan = evaluate(layer, 2, 3, 1, 2)
    assert plan is not None
    wprog = _wave(layer, plan)
    x = jax.random.normal(jax.random.key(2), (2, 20, 20, 8))
    w, b = _weights(layer)
    got = run_layer_megakernel(wprog, x, w, b, relu=True, fuse_pool=True)
    ref = wave_replay_ref(layer, x, w, b, relu=True, fuse_pool=True)
    assert got.shape == ref.shape == (2, layer.pooled_h, layer.pooled_w, 16)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_megakernel_grouped_natural_layout():
    """Grouped layers accumulate per-group Cin/g x Cout/g gemms against
    the natural weight layout (ISSUE 10); the surviving block-diagonal
    reference construction agrees with it and with the direct conv."""
    layer = ConvLayer("g", 14, 14, 8, 12, 3, pad=1, groups=2)
    w, _ = _weights(layer)
    wd = expand_grouped(w, 2)
    assert wd.shape == (3, 3, 8, 12)
    # block-diagonal view: group 0's inputs never feed group 1's features
    assert float(jnp.max(jnp.abs(wd[:, :, :4, 6:]))) == 0.0
    assert float(jnp.max(jnp.abs(wd[:, :, 4:, :6]))) == 0.0
    plan = evaluate(layer, 2, 2, 1, 1)
    x = jax.random.normal(jax.random.key(3), (1, 14, 14, 8))
    got = run_layer_streamed(layer, plan, x, w, mode="megakernel")
    ref = conv2d_direct(x, w, 1, 1, groups=2)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4
    # the block-diagonal dense view computes the same function
    bd = conv2d_direct(x, wd, 1, 1, groups=1)
    assert float(jnp.max(jnp.abs(got - bd))) < 1e-4
    # ... but the megakernel's weight operand is the natural g-x smaller
    kp = lower_kernel_program(_wave(layer, plan))
    assert kp.fan_width == 4 and kp.w_in_kpad == 4


def test_megakernel_masked_write_zeroes_grid_padding():
    """The epilogue's VR/VC masks zero the uniform-grid padding lanes,
    so the padded output is deterministic (not bias-polluted)."""
    layer = ConvLayer("m", 11, 11, 4, 8, 3, pad=1)   # out 11x11
    plan = evaluate(layer, 2, 2, 1, 1)               # blk 6 -> pad 12
    wprog = _wave(layer, plan)
    kp = lower_kernel_program(wprog)
    tab = kp.operand_table()
    assert (kp.out_h_pad, kp.out_w_pad) == (12, 12)
    assert {(r[OP_VR], r[OP_VC]) for r in tab[0]} == \
        {(6, 6), (6, 5), (5, 6), (5, 5)}
    from repro.kernels.wave_replay.kernel import wave_replay_raw
    from repro.kernels.wave_replay.ops import pad_operands
    x = jax.random.normal(jax.random.key(4), (1, 11, 11, 4))
    w, b = _weights(layer)
    xp, wp, bias = pad_operands(kp, x, w, b)
    padded = wave_replay_raw(kp, xp, wp, bias, jnp.asarray(tab))
    assert float(jnp.max(jnp.abs(padded[:, 11:, :, :]))) == 0.0
    assert float(jnp.max(jnp.abs(padded[:, :, 11:, :]))) == 0.0


# ---------------------------------------------------------------------------
# One pallas_call per layer (dispatch counting) + network/serving paths
# ---------------------------------------------------------------------------

def _small_net():
    layers = (ConvLayer("a", 16, 16, 3, 8, 3, pad=1, pool=2),
              ConvLayer("b", 8, 8, 8, 16, 3, pad=1, groups=2))
    weights = []
    for i, l in enumerate(layers):
        w = jax.random.normal(
            jax.random.key(i),
            (l.kernel, l.kernel, l.in_c // l.groups, l.out_c)) * 0.2
        weights.append((w, jnp.full((l.out_c,), 0.1)))
    return layers, weights


def _direct_net(layers, weights, x):
    y = x
    for l, (w, b) in zip(layers, weights):
        y = jnp.maximum(conv2d_direct(y, w, l.stride, l.pad,
                                      groups=l.groups) + b, 0)
        if l.pool > 1:
            y = maxpool_direct(y, l.pool, l.pool_stride or l.pool)
    return y


def test_network_megakernel_one_launch_per_layer():
    """The ISSUE 3 dispatch gate: tracing the megakernel network forward
    launches exactly ONE pallas_call per conv layer — pooling and ReLU
    ride in the epilogue, not in extra dispatches."""
    layers, weights = _small_net()
    plans = [plan_decomposition(l, 64 * 1024) for l in layers]
    programs = compile_network(layers, plans)
    x = jax.random.normal(jax.random.key(5), (2, 16, 16, 3))
    fwd = jax.jit(network_forward_fn(programs, mode="megakernel"))
    ops = network_operands(programs, "megakernel")
    reset_launch_count()
    got = fwd(x, weights, ops)          # one trace
    assert launch_count() == len(layers)
    got2 = fwd(x, weights, ops)         # cached executable: no new trace
    assert launch_count() == len(layers)
    assert jnp.array_equal(got, got2)
    assert float(jnp.max(jnp.abs(
        got - _direct_net(layers, weights, x)))) < 1e-4


def test_network_megakernel_replays_session_plans_when_unbudgeted():
    """``vmem_budget=None`` must replay the session's own programs 1:1
    (no re-planning) and still match."""
    layers, weights = _small_net()
    plans = [plan_decomposition(l, 64 * 1024) for l in layers]
    programs = compile_network(layers, plans)
    x = jax.random.normal(jax.random.key(6), (1, 16, 16, 3))
    fwd = jax.jit(network_forward_fn(programs, mode="megakernel",
                                     vmem_budget=None))
    ops = network_operands(programs, "megakernel", vmem_budget=None)
    got = fwd(x, weights, ops)
    assert float(jnp.max(jnp.abs(
        got - _direct_net(layers, weights, x)))) < 1e-4


def test_session_megakernel_serves_alexnet_prefix():
    """conv1 (pool 3/2) + conv2 (grouped, pooled) through a megakernel
    session: one compile, micro-batch queue intact, donated inputs."""
    stack = ALEXNET_STACK[:2]
    weights = [(_weights(l, key=i, scale=0.05)[0],
                jnp.zeros((l.out_c,))) for i, l in enumerate(stack)]
    x = jax.random.normal(jax.random.key(0), (2, 227, 227, 3))
    ref = _direct_net(stack, weights, x)
    sess = StreamingSession.for_network(stack, weights, max_batch=2,
                                        mode="megakernel")
    assert sess.donate            # donation is the serving default
    y = sess.run_batch(jnp.array(x))      # pass a copy: input is donated
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-3
    assert sess.compile_count == 1
    t0, t1 = sess.submit(x[0]), sess.submit(x[1])
    out0 = sess.result(t0)
    assert float(jnp.max(jnp.abs(out0 - ref[0]))) < 1e-3
    sess.discard(t1)
    assert sess.compile_count == 1        # same batch shape, no retrace


def test_session_donate_flag_plumbed():
    layers, weights = _small_net()
    sess = StreamingSession.for_network(layers, weights,
                                        sram_budget=64 * 1024,
                                        max_batch=2, donate=False)
    assert not sess.donate
    x = jax.random.normal(jax.random.key(7), (2, 16, 16, 3))
    y1 = sess.run_batch(x)
    y2 = sess.run_batch(x)      # donate=False: reuse is always safe
    assert jnp.array_equal(y1, y2)


# ---------------------------------------------------------------------------
# Lowering invariants: rectangular SMEM tables, bounds, masks, chains
# ---------------------------------------------------------------------------

def _assert_kernel_invariants(kp: KernelProgram):
    validate_kernel_program(kp)     # the library's own checks
    tab = kp.operand_table()
    assert tab.shape == (kp.n_chain, kp.n_tiles, KERNEL_OP_COLS)
    assert kp.n_chain * kp.chain_chunk >= kp.wave.n_waves
    l = kp.wave.program.layer
    if l.groups == 1:
        assert kp.c_width == kp.fan_width
    else:
        # natural per-group fan (ISSUE 10): the weight operand never
        # widens to the block-diagonal dense c_width
        assert kp.fan_width == l.in_c // l.groups
        assert kp.w_in_kpad == kp.fan_width
    assert kp.vmem_bytes > 0
    # chain steps cover the padded channel range without overlap
    if kp.wave.program.layer.groups == 1:
        c0s = [int(tab[j][0][OP_C0]) for j in range(kp.n_chain)]
        assert c0s == [j * kp.c_width for j in range(kp.n_chain)]


def test_kernel_lowering_sweep_plan_grid():
    """Deterministic sweep across tile/feat/chain shapes x pool fusion —
    runs even without hypothesis."""
    layers = [
        ConvLayer("s1", 21, 17, 8, 12, 3, stride=2, pad=1),
        ConvLayer("s2", 27, 27, 96, 64, 5, pad=2, groups=2,
                  pool=3, pool_stride=2),
        ConvLayer("s3", 13, 13, 16, 24, 3, pad=1, pool=2),
    ]
    checked = 0
    for layer in layers:
        for th in (1, 2, 3):
            for tw in (1, 2):
                for fs in (1, 2):
                    for cs in (1, 2, 4):
                        plan = evaluate(layer, th, tw, fs, cs)
                        if plan is None:
                            continue
                        wprog = _wave(layer, plan)
                        for fuse in ({False, layer.pool > 1}):
                            for budget in (None, 64 * 1024, 8 * 2 ** 20):
                                _assert_kernel_invariants(
                                    lower_kernel_program(
                                        wprog, relu=True, fuse_pool=fuse,
                                        vmem_budget=budget))
                                checked += 1
    assert checked > 50


@pytest.mark.parametrize("layer", ALEXNET_STACK, ids=lambda l: l.name)
def test_kernel_lowering_alexnet_plans(layer):
    plan = plan_decomposition(layer, 128 * 1024)
    wprog = _wave(layer, plan)
    kp = lower_kernel_program(wprog, vmem_budget=None)
    _assert_kernel_invariants(kp)
    assert kp.n_chain == wprog.n_waves          # 1:1 replay
    kp2 = lower_kernel_program(wprog)           # default budget coarsens
    _assert_kernel_invariants(kp2)
    assert kp2.n_chain <= kp.n_chain


def test_lowering_rejects_poolless_fuse():
    layer = ConvLayer("np", 8, 8, 3, 4, 3, pad=1)
    wprog = _wave(layer, evaluate(layer, 1, 1, 1, 1))
    with pytest.raises(ValueError, match="without a pool"):
        lower_kernel_program(wprog, fuse_pool=True)


def test_validate_rejects_corrupted_table():
    layer = ConvLayer("v", 8, 8, 4, 8, 3, pad=1)
    kp = lower_kernel_program(_wave(layer, evaluate(layer, 2, 1, 1, 1)))
    bad_row = (10_000,) + kp.table[0][0][1:]
    corrupted = dataclasses.replace(
        kp, table=((bad_row,) + kp.table[0][1:],) + kp.table[1:])
    with pytest.raises(ValueError, match="outside the padded"):
        validate_kernel_program(corrupted)


def test_plan_for_vmem_prefers_fewest_steps():
    layer = ALEXNET_STACK[2]        # conv3: 128 KB plan needs 256 waves
    plan = plan_for_vmem(layer, 8 * 2 ** 20, False)
    kp = lower_kernel_program(_wave(layer, plan), relu=True,
                              vmem_budget=8 * 2 ** 20)
    assert kp.n_tiles * kp.n_chain < 256
    assert kp.vmem_bytes <= 8 * 2 ** 20
    # a tiny budget forces real decomposition again
    tight = plan_for_vmem(layer, 512 * 1024, False)
    kp_tight = lower_kernel_program(_wave(layer, tight), relu=True,
                                    vmem_budget=None)
    assert kp_tight.n_tiles * kp_tight.n_chain > 1


# ---------------------------------------------------------------------------
# Property-based lowering checks (skipped cleanly without hypothesis)
# ---------------------------------------------------------------------------

if hypothesis is not None:
    @hypothesis.given(
        st.integers(6, 24), st.integers(6, 24),
        st.integers(1, 8), st.integers(1, 12),
        st.sampled_from([1, 3, 5]), st.sampled_from([1, 2]),
        st.integers(0, 2),
        st.sampled_from([16, 32, 64, 128]),          # SRAM KiB
        st.sampled_from([None, 64 * 1024, 2 ** 23]),  # kernel VMEM
        st.booleans(),
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_kernel_lowering_property_random(h, w, cin, cout, k, stride,
                                             pad, sram_kib, vmem, relu):
        """Randomized geometry x randomized *planner budget* x kernel
        budget: whatever plan_decomposition picks must lower to a valid
        rectangular KernelProgram."""
        layer = ConvLayer("t", h, w, cin, cout, k, stride=stride, pad=pad)
        if layer.out_h <= 0 or layer.out_w <= 0:
            return
        try:
            plan = plan_decomposition(layer, sram_kib * 1024)
        except ValueError:
            return                      # no feasible plan at this budget
        wprog = _wave(layer, plan)
        _assert_kernel_invariants(lower_kernel_program(
            wprog, relu=relu, vmem_budget=vmem))

    @hypothesis.given(
        st.integers(8, 20), st.integers(8, 20),
        st.integers(2, 6), st.integers(2, 8),
        st.sampled_from([2, 3]), st.integers(1, 2), st.integers(1, 2),
        st.integers(1, 4),
    )
    @hypothesis.settings(max_examples=12, deadline=None)
    def test_megakernel_matches_reference_random(h, w, cin, cout, k,
                                                 th, tw, cs):
        """Randomized small geometries: megakernel output vs the XLA
        oracle (end-to-end through padding, tables, and epilogue)."""
        layer = ConvLayer("r", h, w, cin, cout, k, pad=1)
        plan = evaluate(layer, th, tw, 1, cs)
        if plan is None:
            return
        x = jax.random.normal(jax.random.key(0), (1, h, w, cin))
        wts, b = _weights(layer)
        got = wave_replay_layer(lower_kernel_program(_wave(layer, plan)),
                                x, wts, b)
        ref = wave_replay_ref(layer, x, wts, b)
        assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


# ---------------------------------------------------------------------------
# Residual epilogue (ISSUE 5): the accumulation-SRAM add in the kernel
# ---------------------------------------------------------------------------

def test_megakernel_residual_epilogue_matches_ref():
    """residual=True lowers one extra operand, added after bias and
    before ReLU — compared against the XLA oracle with the same order."""
    layer = ConvLayer("res", 12, 12, 8, 8, 3, pad=1)
    plan = evaluate(layer, 2, 2, 1, 2)
    kp = lower_kernel_program(partition_waves(compile_layer(layer, plan)),
                              relu=True, residual=True, vmem_budget=None)
    x = jax.random.normal(jax.random.key(0), (2, 12, 12, 8))
    w = jax.random.normal(jax.random.key(1), (3, 3, 8, 8)) * 0.2
    b = jax.random.normal(jax.random.key(2), (8,)) * 0.1
    r = jax.random.normal(jax.random.key(3), (2, 12, 12, 8))
    got = wave_replay_layer(kp, x, w, b, residual=r)
    ref = wave_replay_ref(layer, x, w, b, relu=True, residual=r)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_megakernel_residual_validation():
    layer = ConvLayer("resv", 8, 8, 4, 4, 3, pad=1, pool=2)
    plan = evaluate(layer, 1, 1, 1, 1)
    wprog = partition_waves(compile_layer(layer, plan))
    with pytest.raises(ValueError, match="residual add cannot fuse"):
        lower_kernel_program(wprog, relu=True, fuse_pool=True,
                             residual=True)
    nopool = ConvLayer("resv2", 8, 8, 4, 4, 3, pad=1)
    kp = lower_kernel_program(
        partition_waves(compile_layer(nopool, evaluate(nopool, 1, 1, 1, 1))),
        residual=True, vmem_budget=None)
    x = jnp.zeros((1, 8, 8, 4))
    w = jnp.zeros((3, 3, 4, 4))
    with pytest.raises(ValueError, match="needs the residual"):
        wave_replay_layer(kp, x, w)
    kp_plain = lower_kernel_program(
        partition_waves(compile_layer(nopool, evaluate(nopool, 1, 1, 1, 1))),
        residual=False, vmem_budget=None)
    with pytest.raises(ValueError, match="without residual"):
        from repro.kernels.wave_replay.kernel import wave_replay_raw
        from repro.kernels.wave_replay.ops import pad_operands
        xp, wp, bias = pad_operands(kp_plain, x, w, None)
        wave_replay_raw(kp_plain, xp, wp, bias,
                        jnp.asarray(kp_plain.operand_table()),
                        residual=jnp.zeros((1, 8, 8, 4)))
