"""Paper §7 'supports most popular CNNs': VGG-16 / ResNet-18 layer tables
decompose under the 128 KB budget; nameplate op counts check out; the
ResNet-18 planner edge cases (1x1 stride-2 projections, the 7x7/2 stem
with its overlapping 3/2 pool) execute correctly, not just plan
(ISSUE 5 satellite — these shapes used to be smoke-planned only)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.decomposition import ConvLayer, plan_decomposition
from repro.core.model_zoo import (RESNET18_LAYERS, VGG16_LAYERS,
                                  network_graph, resnet18_graph,
                                  vgg16_graph)
from repro.core.streaming import (conv2d_direct, maxpool_direct,
                                  run_layer_interpreted,
                                  run_layer_streamed)

BUDGET = 128 * 1024


def test_vgg16_all_layers_fit():
    for l in VGG16_LAYERS:
        assert plan_decomposition(l, BUDGET).sram_needed <= BUDGET


def test_resnet18_all_layers_fit():
    for l in RESNET18_LAYERS:
        assert plan_decomposition(l, BUDGET).sram_needed <= BUDGET


# ---------------------------------------------------------------------------
# ResNet-18 planner edge cases: plan AND execute (regression)
# ---------------------------------------------------------------------------

PROJ_LAYERS = [l for l in RESNET18_LAYERS if l.name.startswith("res_proj")]


@pytest.mark.parametrize("layer", PROJ_LAYERS, ids=lambda l: l.name)
def test_projection_conv_plans_under_budget(layer):
    """1x1 stride-2 projections: planned under 128 KB with a positive
    working set and full output coverage."""
    plan = plan_decomposition(layer, BUDGET)
    assert 0 < plan.sram_needed <= BUDGET
    assert plan.tiles_h * plan.tiles_w * plan.feat_splits \
        * plan.in_splits == plan.passes


@pytest.mark.parametrize("mode", ["interpret", "scan", "wave",
                                  "megakernel"])
def test_projection_conv_executes_correctly(mode):
    """The res_proj geometry at test scale: k=1, stride=2, no pad — the
    conv window never reaches the last input row/col ((in - 1) % 2 != 0),
    the trailing-trim path every executor must get right."""
    layer = ConvLayer("proj", 14, 14, 8, 16, 1, stride=2)
    plan = plan_decomposition(layer, 16 * 1024)
    x = jax.random.normal(jax.random.key(0), (2, 14, 14, 8))
    w = jax.random.normal(jax.random.key(1), (1, 1, 8, 16)) * 0.2
    got = run_layer_streamed(layer, plan, x, w, mode=mode)
    ref = conv2d_direct(x, w, 2, 0)
    assert got.shape == ref.shape == (2, 7, 7, 16)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_stem_plans_under_budget_and_executes():
    """The 7x7 stride-2, pad-3 stem with its overlapping 3/2 max-pool:
    plans under 128 KB at nameplate dims; executes correctly (with the
    pool applied) at test scale."""
    stem = RESNET18_LAYERS[0]
    plan = plan_decomposition(stem, BUDGET)
    assert plan.sram_needed <= BUDGET
    small = ConvLayer("stem_s", 32, 32, 3, 8, 7, stride=2, pad=3,
                      pool=3, pool_stride=2)
    plan_s = plan_decomposition(small, 32 * 1024)
    x = jax.random.normal(jax.random.key(2), (1, 32, 32, 3))
    w = jax.random.normal(jax.random.key(3), (7, 7, 3, 8)) * 0.1
    ref = maxpool_direct(conv2d_direct(x, w, 2, 3), 3, 2)
    for mode in ("interpret", "scan", "wave"):
        got = run_layer_streamed(small, plan_s, x, w, mode=mode)
        got = maxpool_direct(got, 3, 2)
        assert float(jnp.max(jnp.abs(got - ref))) < 1e-4, mode


def test_stem_megakernel_fused_pool_matches():
    """The graph megakernel path fuses the stem's 3/2 pool into the
    kernel epilogue — overlapping pool windows on a stride-2 conv."""
    from repro.core.schedule import (compile_layer, lower_kernel_program,
                                     partition_waves)
    from repro.kernels.wave_replay.ops import wave_replay_layer
    small = ConvLayer("stem_s", 32, 32, 3, 8, 7, stride=2, pad=3,
                      pool=3, pool_stride=2)
    plan = plan_decomposition(small, 32 * 1024)
    kp = lower_kernel_program(partition_waves(compile_layer(small, plan)),
                              relu=True, fuse_pool=True, vmem_budget=None)
    x = jax.random.normal(jax.random.key(4), (1, 32, 32, 3))
    w = jax.random.normal(jax.random.key(5), (7, 7, 3, 8)) * 0.1
    got = wave_replay_layer(kp, x, w)
    ref = maxpool_direct(jnp.maximum(conv2d_direct(x, w, 2, 3), 0), 3, 2)
    assert got.shape == ref.shape
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_projection_interpreted_matches_scan_bit_exact():
    """Regression guard for the schedule's trailing-trim arithmetic on
    even-input stride-2 1x1 convs (no partial sums -> bit-identical)."""
    layer = ConvLayer("proj", 56, 56, 4, 8, 1, stride=2)
    plan = plan_decomposition(layer, 16 * 1024)
    x = jax.random.normal(jax.random.key(6), (1, 56, 56, 4))
    w = jax.random.normal(jax.random.key(7), (1, 1, 4, 8)) * 0.2
    a = run_layer_interpreted(layer, plan, x, w)
    b = run_layer_streamed(layer, plan, x, w, mode="scan")
    assert jnp.array_equal(a, b)


def test_network_graph_registry():
    assert network_graph("vgg16").name == "vgg16"
    g = network_graph("resnet18")
    assert len([n for n in g.nodes if n.op == "add"]) == 8
    with pytest.raises(ValueError, match="unknown network"):
        network_graph("lenet")


def test_full_size_graphs_plan_under_128k():
    """Every conv node of the nameplate VGG-16 and ResNet-18 graphs
    (projections and stem included) decomposes under the paper budget."""
    from repro.core.streaming import plan_graph
    for g in (vgg16_graph(), resnet18_graph()):
        plans = plan_graph(g, BUDGET)
        assert all(p.sram_needed <= BUDGET for p in plans.values())


def test_vgg16_total_ops_matches_literature():
    # VGG-16 conv ops ~30.7 GFLOPs (2 ops/MAC) at 224x224
    total = sum(l.num_ops for l in VGG16_LAYERS) / 1e9
    assert 29.0 < total < 32.0


def test_alexnet_config_importable():
    from repro.configs import get_config
    cfg = get_config("alexnet")
    assert cfg.name == "alexnet" and len(cfg.layers) == 5
