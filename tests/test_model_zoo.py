"""Paper §7 'supports most popular CNNs': VGG-16 / ResNet-18 layer tables
decompose under the 128 KB budget; nameplate op counts check out."""
from repro.core.decomposition import plan_decomposition
from repro.core.model_zoo import RESNET18_LAYERS, VGG16_LAYERS

BUDGET = 128 * 1024


def test_vgg16_all_layers_fit():
    for l in VGG16_LAYERS:
        assert plan_decomposition(l, BUDGET).sram_needed <= BUDGET


def test_resnet18_all_layers_fit():
    for l in RESNET18_LAYERS:
        assert plan_decomposition(l, BUDGET).sram_needed <= BUDGET


def test_vgg16_total_ops_matches_literature():
    # VGG-16 conv ops ~30.7 GFLOPs (2 ops/MAC) at 224x224
    total = sum(l.num_ops for l in VGG16_LAYERS) / 1e9
    assert 29.0 < total < 32.0


def test_alexnet_config_importable():
    from repro.configs import get_config
    cfg = get_config("alexnet")
    assert cfg.name == "alexnet" and len(cfg.layers) == 5
