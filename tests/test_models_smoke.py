"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.configs.base import SHAPES, TrainConfig, applicable_shapes
from repro.data.pipeline import lm_batch
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.models.module import init_params
from repro.train.steps import init_train_state, make_train_step


from conftest import optimization_barrier_differentiable

# pre-existing seed failure, triaged (ISSUE 5 satellite): the pinned
# jax has no differentiation rule for optimization_barrier, which the
# loss path uses to pin the bf16 cast before FSDP gathers
# (src/repro/train/losses.py) — every test that takes grads dies.
# Applied per grad-taking test (NOT module-wide), so the grad-free
# tests keep failing loudly on real regressions.
xfail_no_optbar_grad = pytest.mark.xfail(
    condition=not optimization_barrier_differentiable(),
    reason="installed jax cannot differentiate optimization_barrier "
           "(train/losses.py pins the compute-dtype cast with it); "
           "needs a newer jax pin",
    strict=False)

ASSIGNED_DIMS = {  # exact dims from the assignment table
    "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
    "command_r_35b": (40, 8192, 64, 8, 22528, 256000),
    "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
    "qwen3_1p7b": (28, 2048, 16, 8, 6144, 151936),
    "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
    "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
    "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
    "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
    "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 1536, 151936),
    "xlstm_125m": (12, 768, 4, 4, 0, 50304),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dims_match_assignment(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED_DIMS[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v
    # every layer type is defined
    assert len(cfg.layer_types) == cfg.n_layers


@xfail_no_optbar_grad
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = dataclasses.replace(reduced_config(arch), compute_dtype="float32")
    B, S = 2, 16
    batch = lm_batch(0, 0, B, S, cfg.vocab_size)
    if cfg.n_encoder_layers:
        params = init_params(ED.encdec_defs(cfg), jax.random.key(0))
        frames = jnp.zeros((B, 8, cfg.d_model), jnp.float32)
        logits = ED.apply_encdec(cfg, params, frames, batch["tokens"])
        batch = {**batch, "frames": frames}
    else:
        params = init_params(T.lm_defs(cfg), jax.random.key(0))
        if cfg.frontend == "vision_patches":
            batch["vision_embeds"] = jnp.zeros((B, 4, cfg.d_model))
        logits, _, _ = T.apply_lm(cfg, params, batch["tokens"],
                                  extra_embeds=batch.get("vision_embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one train step
    state = init_train_state(cfg, params)
    step_fn = jax.jit(make_train_step(cfg, TrainConfig(learning_rate=1e-3)))
    new_state, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    diff = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        state["params"], new_state["params"]))
    assert max(diff) > 0


@pytest.mark.parametrize("arch", ["gemma3_4b", "recurrentgemma_2b",
                                  "xlstm_125m"])
def test_long_context_archs_are_subquadratic(arch):
    assert get_config(arch).subquadratic


def test_long_500k_skips_are_documented():
    expect_skip = {"command_r_35b", "mistral_large_123b", "qwen3_1p7b",
                   "seamless_m4t_medium", "qwen2_vl_72b", "dbrx_132b",
                   "qwen3_moe_235b_a22b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = [s.name for s in applicable_shapes(cfg)]
        if arch in expect_skip:
            assert "long_500k" not in names
        else:
            assert "long_500k" in names


@pytest.mark.parametrize("arch", ["qwen3_1p7b", "gemma3_4b",
                                  "recurrentgemma_2b", "xlstm_125m"])
def test_decode_matches_full_forward(arch):
    cfg = dataclasses.replace(reduced_config(arch), compute_dtype="float32")
    params = init_params(T.lm_defs(cfg), jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full_logits, _, _ = T.apply_lm(cfg, params, toks)
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache, _ = T.apply_lm(cfg, params, toks[:, t:t + 1],
                                  cache=cache, cache_pos=t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.max(jnp.abs(dec - full_logits)) < 1e-3


def test_param_counts_in_expected_range():
    """Full configs should be in the ballpark of their nameplate sizes."""
    expected = {  # arch -> (low, high) in billions
        "gemma3_4b": (3.0, 6.0),
        "command_r_35b": (30, 40),
        "mistral_large_123b": (110, 135),
        "qwen3_1p7b": (1.2, 2.3),
        "recurrentgemma_2b": (2.0, 4.0),
        "qwen2_vl_72b": (65, 80),
        "dbrx_132b": (110, 145),
        "qwen3_moe_235b_a22b": (200, 260),
        "xlstm_125m": (0.08, 0.2),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"
