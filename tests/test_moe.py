"""MoE routing invariants + dispatch correctness vs a naive per-token loop."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import MoEConfig
from repro.models.moe import _capacity, apply_moe, moe_defs
from repro.models.module import init_params


def _cfg(num_experts=4, top_k=2, cf=8.0):
    cfg = reduced_config("dbrx_132b")
    return dataclasses.replace(
        cfg, compute_dtype="float32",
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff_expert=32,
                      capacity_factor=cf))


def _naive_moe(cfg, p, x):
    """Per-token loop oracle (no capacity drops — use huge cf in cfg)."""
    m = cfg.moe
    B, S, D = x.shape
    xt = np.asarray(x.reshape(-1, D), np.float32)
    logits = xt @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        topk = np.argsort(-np.asarray(probs[t]))[:m.top_k]
        w = np.asarray(probs[t])[topk]
        w = w / w.sum()
        for e, we in zip(topk, w):
            g = xt[t] @ np.asarray(p["w_gate"][e])
            u = xt[t] @ np.asarray(p["w_up"][e])
            h = np.asarray(jax.nn.silu(jnp.asarray(g))) * u
            out[t] += we * (h @ np.asarray(p["w_down"][e]))
    return out.reshape(B, S, D)


def test_moe_matches_naive_loop_without_drops():
    cfg = _cfg(cf=64.0)  # capacity huge -> nothing dropped
    p = init_params(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 6, cfg.d_model))
    got, aux = apply_moe(cfg, p, x)
    assert float(aux["moe_drop_frac"]) == 0.0
    ref = _naive_moe(cfg, p, x)
    assert np.max(np.abs(np.asarray(got) - ref)) < 1e-4


def test_moe_drops_under_tight_capacity():
    cfg = _cfg(cf=0.5)
    p = init_params(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    got, aux = apply_moe(cfg, p, x)
    assert 0.0 < float(aux["moe_drop_frac"]) < 1.0
    assert bool(jnp.all(jnp.isfinite(got)))


def test_moe_aux_loss_uniform_is_one():
    """Perfectly uniform routing gives aux loss ~= 1 (Switch normalisation)."""
    cfg = _cfg()
    p = init_params(moe_defs(cfg), jax.random.key(0))
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    _, aux = apply_moe(cfg, p, x)
    assert abs(float(aux["moe_aux_loss"]) - 1.0) < 0.05


def test_capacity_rounding():
    cfg = _cfg(num_experts=4, top_k=2, cf=1.0)
    c = _capacity(100, cfg)
    assert c % 8 == 0 and c >= 100 * 2 / 4


def test_moe_grads_flow_to_all_parts():
    cfg = _cfg(cf=8.0)
    p = init_params(moe_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))

    def loss(p):
        out, aux = apply_moe(cfg, p, x)
        return jnp.sum(out ** 2) + aux["moe_aux_loss"]

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name
