"""Observability subsystem (ISSUE 9): span tracer, metrics registry,
Chrome/Perfetto export, and the execute-span == launch-count invariant.

The acceptance artifact: per-layer megakernel execute spans (and
per-chain graphkernel spans) are recorded by the SAME code path as the
trace-time launch counters (kernels/common.py LaunchCounter), so the
span count equals launch_count() by construction — verified here on
the real AlexNet stack.
"""
import json
import threading

import jax
import jax.numpy as jnp
import pytest

import repro.kernels.wave_replay.ops as wr
import repro.kernels.wave_replay_q.ops as wrq
from repro.core.decomposition import ALEXNET_STACK, plan_decomposition
from repro.core.graph import chain_graph
from repro.core.streaming import (compile_graph, graph_forward_fn,
                                  graph_operands, plan_graph)
from repro.models.cnn import init_graph_weights
from repro.obs import (MetricsRegistry, Tracer, chrome_trace_events,
                       current_tracer, render_metrics, reset_metrics,
                       set_tracer, use_registry, use_tracer,
                       write_chrome_trace)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------

def test_spans_nest_and_carry_attrs():
    t = Tracer()
    with t.span("outer", cat="plan", graph="g") as outer:
        with t.span("inner", cat="lower") as inner:
            pass
    assert outer.parent_id is None
    assert inner.parent_id == outer.id
    assert outer.attrs["graph"] == "g"
    assert outer.end_ns is not None and inner.end_ns is not None
    # child lies within the parent interval
    assert outer.start_ns <= inner.start_ns <= inner.end_ns <= outer.end_ns


def test_span_closes_with_error_attribute_on_exception():
    """A failing node still closes its span — with an ``error``
    attribute — so traces of failing runs are complete."""
    t = Tracer()
    with pytest.raises(ValueError):
        with t.span("outer", cat="run"):
            with t.span("boom", cat="execute"):
                raise ValueError("tile does not fit")
    outer, boom = t.spans()
    assert boom.attrs["error"] == "ValueError: tile does not fit"
    assert boom.end_ns is not None
    # the parent also closed (and recorded the propagating error)
    assert outer.end_ns is not None
    assert "error" in outer.attrs
    # nesting stack unwound: a new span is again a root
    with t.span("after"):
        pass
    assert t.spans()[-1].parent_id is None


def test_disabled_helpers_are_noops():
    assert current_tracer() is None
    cm = obs_trace.span("anything", cat="plan")   # shared nullcontext
    with cm:
        pass
    obs_trace.event("nothing")                    # must not raise
    t = Tracer()
    with use_tracer(t):
        with obs_trace.span("live", cat="plan"):
            pass
        # use_tracer(None) must NOT mask the outer tracer
        with use_tracer(None):
            with obs_trace.span("still_live", cat="plan"):
                pass
    assert current_tracer() is None
    assert [s.name for s in t.spans("plan")] == ["live", "still_live"]


def test_tracer_thread_local_stacks():
    t = Tracer()
    done = threading.Event()

    def worker():
        with t.span("w", cat="run"):
            done.wait(2.0)

    with use_tracer(t):
        th = threading.Thread(target=worker)
        th.start()
        # main-thread span must not become a child of the worker's span
        with t.span("m", cat="run") as m:
            pass
        done.set()
        th.join()
    assert m.parent_id is None
    w = [s for s in t.spans() if s.name == "w"][0]
    assert w.parent_id is None
    assert w.tid != m.tid


def test_tracer_bounded_and_truncation_reported():
    t = Tracer(max_spans=2)
    for i in range(4):
        with t.span(f"s{i}"):
            pass
    assert len(t.spans()) == 2
    assert t.dropped == 2
    payload = chrome_trace_events(t)
    assert payload["metadata"]["dropped"] == 2


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_registry_isolation_and_snapshot():
    reg = MetricsRegistry()
    with use_registry(reg):
        obs_metrics.registry().counter("kernel_launches").inc(3)
        obs_metrics.registry().gauge("train.loss").set(1.5)
        obs_metrics.registry().histogram("lat").observe(0.01)
    # nothing leaked into the default registry
    assert obs_metrics.registry().counter("kernel_launches").value == 0
    snap = reg.snapshot()
    assert snap["counters"]["kernel_launches"] == 3
    assert snap["gauges"]["train.loss"] == 1.5
    assert snap["histograms"]["lat"]["count"] == 1
    reg.reset()
    assert reg.counter("kernel_launches").value == 0
    assert reg.histogram("lat").count == 0


def test_histogram_buckets_and_stats():
    reg = MetricsRegistry()
    h = reg.histogram("t", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["buckets"] == {"0.1": 1, "1.0": 1, "+inf": 1}
    assert snap["count"] == 3
    assert snap["min"] == 0.05 and snap["max"] == 2.0
    assert abs(h.mean - (0.05 + 0.5 + 2.0) / 3) < 1e-12


def test_render_metrics_plain_text():
    reg = MetricsRegistry()
    reg.counter("kernel_launches.wave_replay").inc(7)
    reg.histogram("session.request_latency_s").observe(0.002)
    text = render_metrics(reg)
    assert "kernel_launches.wave_replay 7" in text
    assert "session.request_latency_s count=1" in text


def test_launch_counter_shims_and_registry_feed():
    """The deduplicated LaunchCounter keeps the launch_count()/
    reset_launch_count() shims AND mirrors into the metrics registry."""
    reg = MetricsRegistry()
    with use_registry(reg):
        wr.reset_launch_count()
        with wr.launches.record("c1", "megakernel"):
            pass
        with wr.launches.record("c2", "megakernel"):
            pass
        assert wr.launch_count() == 2
        assert reg.counter("kernel_launches").value == 2
        assert reg.counter("kernel_launches.wave_replay").value == 2
        wr.reset_launch_count()
        assert wr.launch_count() == 0


def test_degradation_counter_is_registry_scoped():
    from repro.runtime.fallback import (DegradationEvent,
                                        degradation_event_count,
                                        record_event,
                                        reset_degradation_events)
    ev = DegradationEvent(node="c1", from_mode="megakernel",
                          to_mode="wave", stage="plan",
                          cause="ValueError: boom", retry=0)
    with use_registry(MetricsRegistry()):
        events = []
        record_event(events, ev)
        assert degradation_event_count() == 1
        assert obs_metrics.registry() \
            .counter("degradation_events.plan").value == 1
    # the fresh-registry increments never touched the default registry
    assert degradation_event_count() == 0
    record_event([], ev)
    assert degradation_event_count() == 1
    reset_degradation_events()
    assert degradation_event_count() == 0


# ---------------------------------------------------------------------------
# Export round-trip
# ---------------------------------------------------------------------------

def test_chrome_trace_roundtrip_and_child_containment(tmp_path):
    t = Tracer()
    with t.span("parent", cat="run", mode="megakernel"):
        with t.span("child_a", cat="execute", node="c1"):
            pass
        with t.span("child_b", cat="execute", node="c2"):
            pass
        t.event("marker", cat="request", ticket=1)
    path = tmp_path / "trace.json"
    n = write_chrome_trace(str(path), t)
    payload = json.loads(path.read_text())      # round-trips
    assert len(payload["traceEvents"]) == n == 4
    ev = {e["name"]: e for e in payload["traceEvents"]}
    parent, a, b = ev["parent"], ev["child_a"], ev["child_b"]
    for e in (parent, a, b):
        assert e["ph"] == "X" and e["dur"] >= 0
    assert ev["marker"]["ph"] == "i"
    # children fit inside the parent, and siblings do not overlap:
    # monotonic, a closes before b opens
    assert parent["ts"] <= a["ts"]
    assert a["ts"] + a["dur"] <= b["ts"]
    assert b["ts"] + b["dur"] <= parent["ts"] + parent["dur"]
    assert a["args"]["node"] == "c1"
    # ts list is sorted (Perfetto wants ordered events)
    ts = [e["ts"] for e in payload["traceEvents"]]
    assert ts == sorted(ts)


def test_chrome_trace_serializes_arbitrary_attrs():
    t = Tracer()
    with t.span("s", cat="plan", shape=(1, 2, 3), plan=object()):
        pass
    json.dumps(chrome_trace_events(t))   # must not raise


# ---------------------------------------------------------------------------
# Execute spans == launch counters (the acceptance criterion), AlexNet
# ---------------------------------------------------------------------------

def _alexnet_setup(mode):
    g = chain_graph(tuple(ALEXNET_STACK), name="alexnet_obs")
    plans = plan_graph(g, 128 * 1024)
    progs = compile_graph(g, plans)
    ws = init_graph_weights(g, jax.random.key(0))
    x = jnp.zeros((1,) + g.in_shape)
    fn = graph_forward_fn(g, progs, mode=mode)
    ops = graph_operands(g, progs, mode=mode)
    return fn, x, ws, ops


@pytest.mark.parametrize("mode", ["megakernel", "graphkernel"])
def test_execute_span_count_matches_launch_count_alexnet(mode):
    """Tracing one AlexNet forward records exactly one ``execute`` span
    per kernel launch — per conv layer in megakernel mode, per fused
    chain in graphkernel mode — and the span count equals the
    trace-time launch counter."""
    fn, x, ws, ops = _alexnet_setup(mode)
    t = Tracer()
    with use_tracer(t):
        wr.reset_launch_count()
        wrq.reset_launch_count()
        jax.eval_shape(fn, x, ws, ops)     # one trace, no execution
    launches = wr.launch_count() + wrq.launch_count()
    assert launches > 0
    ex = t.spans("execute")
    assert len(ex) == launches
    if mode == "megakernel":
        assert launches == len(ALEXNET_STACK)
        assert sorted(s.attrs["node"] for s in ex) \
            == sorted(l.name for l in ALEXNET_STACK)
        assert all(s.attrs["kind"] == "megakernel" for s in ex)
    else:
        # fused chains record kind=graphkernel; a single-node chain
        # executes through the per-layer megakernel path
        assert {s.attrs["kind"] for s in ex} \
            <= {"graphkernel", "megakernel"}
        assert any(s.attrs["kind"] == "graphkernel" for s in ex)
    # registry mirror agrees with the shim counters
    # (default registry: the autouse conftest fixture resets it)
    assert obs_metrics.registry().counter("kernel_launches").value \
        == launches


def test_plan_and_lower_spans_emitted():
    g = chain_graph(tuple(ALEXNET_STACK[:2]), name="alexnet_obs2")
    t = Tracer()
    with use_tracer(t):
        plans = plan_graph(g, 128 * 1024)
        compile_graph(g, plans)
    plan_spans = t.spans("plan")
    assert [s.name for s in plan_spans] == ["plan:alexnet_obs2"]
    assert plan_spans[0].attrs["dram_traffic_bytes"] > 0
    assert [s.name for s in t.spans("lower")] == ["lower:alexnet_obs2"]
    # modelled traffic also landed in the metrics registry
    assert obs_metrics.registry() \
        .counter("modelled_dram_traffic_bytes").value \
        == plan_spans[0].attrs["dram_traffic_bytes"]


# ---------------------------------------------------------------------------
# Session lifecycle + health merge
# ---------------------------------------------------------------------------

def _tiny_graph():
    from repro.core.decomposition import ConvLayer
    layers = (ConvLayer("t1", 8, 8, 3, 4, 3, stride=1, pad=1),
              ConvLayer("t2", 8, 8, 4, 4, 3, stride=1, pad=1))
    return chain_graph(layers, name="tiny_obs")


def test_session_lifecycle_spans_and_health_metrics():
    from repro.launch.session import StreamingSession
    g = _tiny_graph()
    ws = init_graph_weights(g, jax.random.key(1))
    t = Tracer()
    with use_registry(MetricsRegistry()) as reg:
        sess = StreamingSession.for_graph(g, ws, sram_budget=64 * 1024,
                                          max_batch=2, mode="scan",
                                          tracer=t)
        imgs = jax.random.normal(jax.random.key(2), (3,) + g.in_shape)
        tk0 = sess.submit(imgs[0])
        tk1 = sess.submit(imgs[1])        # fills the batch -> auto flush
        jax.block_until_ready(sess.result(tk0))
        sess.result(tk1)
        tk2 = sess.submit(imgs[2])
        sess.flush()
        sess.result(tk2)
        h = sess.health()
        snap = reg.snapshot()
    # plan/lower spans from construction, run_batch + flush spans from
    # serving, enqueue/reply instants per request — all on one tracer
    assert t.span_count("plan") >= 1
    assert t.span_count("lower") >= 1
    runs = [s.name for s in t.spans("run")]
    assert runs.count("run_batch") == 2
    assert [s.name for s in t.spans("request")] == ["flush", "flush"]
    enq = [e for e in t.events("request") if e["name"] == "enqueue"]
    assert [e["attrs"]["ticket"] for e in enq] == [tk0, tk1, tk2]
    replies = [e for e in t.events("request") if e["name"] == "reply"]
    assert len(replies) == 3
    # first run_batch compiled, second hit the session executable cache
    kinds = [s.name for s in t.spans("compile")]
    assert kinds.count("compile") >= 1
    # metrics: health() merges the registry snapshot
    assert h["metrics"]["counters"]["session.calls"] == 2
    assert snap["counters"]["session.compiles"] == 1
    fill = snap["histograms"]["session.batch_fill_ratio"]
    assert fill["count"] == 2
    assert fill["min"] == 0.5 and fill["max"] == 1.0
    assert snap["histograms"]["session.request_latency_s"]["count"] == 3
    assert snap["gauges"]["session.queue_depth"] == 0


def test_executor_cache_metrics():
    from repro.core import streaming as S
    reg = MetricsRegistry()
    with use_registry(reg):
        S._EXECUTOR_CACHE.clear()
        calls = []
        S._call_cached(("obs_test", 1), lambda: calls.append(1) or
                       (lambda: 42), )
        S._call_cached(("obs_test", 1), lambda: calls.append(1) or
                       (lambda: 42), )
        S._EXECUTOR_CACHE.pop(("obs_test", 1), None)
    assert len(calls) == 1
    assert reg.counter("executor_cache.misses").value == 1
    assert reg.counter("executor_cache.hits").value == 1
