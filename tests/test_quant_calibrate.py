"""PTQ calibration edge cases (ISSUE 4 satellite): all-zero channels,
single-image calibration sets, percentile-clip saturation, and the
fixed-point requantize parameter derivation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decomposition import ConvLayer
from repro.core.quantization import (INT8_QMAX, quantize_int8_sym,
                                     requant_params, requantize_i32,
                                     rounding_rshift)
from repro.quant.calibrate import (QuantizedNetwork, activation_scale,
                                   calibrate_layer, calibrate_network,
                                   quantize_layer,
                                   quantize_weights_per_channel)

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # dev-only dependency (requirements.txt)
    hypothesis = None


def _small_stack():
    layers = (ConvLayer("a", 16, 16, 3, 8, 3, pad=1, pool=2),
              ConvLayer("b", 8, 8, 8, 16, 3, pad=1, groups=2))
    weights = []
    for i, l in enumerate(layers):
        w = jax.random.normal(
            jax.random.key(i),
            (l.kernel, l.kernel, l.in_c // l.groups, l.out_c)) * 0.2
        weights.append((w, jnp.full((l.out_c,), 0.1)))
    return layers, weights


# ---------------------------------------------------------------------------
# all-zero channels
# ---------------------------------------------------------------------------

def test_all_zero_weight_channel_gets_safe_scale():
    w = np.random.default_rng(0).normal(size=(3, 3, 4, 8)).astype(np.float32)
    w[..., 3] = 0.0                       # dead output channel
    wq, scale = quantize_weights_per_channel(w)
    assert scale[3] == 1.0                # guard, not 0 or inf
    assert np.all(wq[..., 3] == 0)
    # the dead channel round-trips exactly; live channels stay accurate
    deq = wq.astype(np.float32) * scale
    assert np.array_equal(deq[..., 3], w[..., 3])
    assert np.max(np.abs(deq - w)) <= 0.5 * scale.max() + 1e-6


def test_all_zero_weights_layer_quantizes_and_runs():
    """A fully dead layer must still produce finite requant params and a
    constant (bias-only) integer output."""
    layer = ConvLayer("z", 8, 8, 4, 6, 3, pad=1)
    w = jnp.zeros((3, 3, 4, 6))
    b = jnp.full((6,), 0.25)
    lq = quantize_layer(layer, w, b, in_scale=0.05, out_scale=0.01)
    assert np.all(np.isfinite(lq.m)) and np.all(lq.m >= 1)
    assert np.all(lq.shift >= lq.pre_shift)
    from repro.kernels.wave_replay_q.ref import quant_layer_ref_from_quant
    xq = jnp.zeros((1, 8, 8, 4), jnp.int8)
    y = quant_layer_ref_from_quant(layer, xq, lq)
    # bias 0.25 at out_scale 0.01 -> q = 25 everywhere
    assert jnp.array_equal(y, jnp.full_like(y, 25))


def test_all_zero_activations_fall_back_to_unit_scale():
    assert activation_scale(np.zeros(100), "absmax") == 1.0
    assert activation_scale(np.zeros(100), "percentile") == 1.0
    assert activation_scale(np.zeros(0), "percentile") == 1.0


# ---------------------------------------------------------------------------
# single-image calibration sets
# ---------------------------------------------------------------------------

def test_single_image_calibration_set():
    layers, weights = _small_stack()
    x1 = jax.random.normal(jax.random.key(5), (1, 16, 16, 3))
    qnet = calibrate_network(layers, weights, x1)    # one (1,H,W,C) batch
    assert isinstance(qnet, QuantizedNetwork)
    assert qnet.quants[0].out_scale == qnet.quants[1].in_scale
    # scales are usable: quantizing the calibration image saturates at
    # most the percentile tail
    xq = quantize_int8_sym(x1, qnet.in_scale)
    assert int(jnp.max(jnp.abs(xq))) == INT8_QMAX


def test_calibration_requires_at_least_one_batch():
    layers, weights = _small_stack()
    with pytest.raises(ValueError, match="at least one batch"):
        calibrate_network(layers, weights, iter([]))


def test_multi_batch_observations_pool():
    """absmax over several batches = absmax of their union: a later
    batch with a bigger outlier must widen the scale."""
    layers, weights = _small_stack()
    small = jax.random.normal(jax.random.key(1), (1, 16, 16, 3)) * 0.1
    big = jax.random.normal(jax.random.key(2), (1, 16, 16, 3)) * 5.0
    q_small = calibrate_network(layers, weights, small, method="absmax")
    q_both = calibrate_network(layers, weights, [small, big],
                               method="absmax")
    assert q_both.in_scale > q_small.in_scale


# ---------------------------------------------------------------------------
# percentile-clip saturation
# ---------------------------------------------------------------------------

def test_percentile_clip_saturates_outliers():
    """Activations beyond the percentile clip at exactly ±127 — the
    planned trade: a few saturated pixels for a finer LSB."""
    rng = np.random.default_rng(7)
    acts = rng.normal(size=20_000).astype(np.float32)
    acts[:20] = 1000.0                    # 0.1% outliers
    s_pct = activation_scale(acts, "percentile", 99.0)
    s_max = activation_scale(acts, "absmax")
    assert s_pct < s_max / 50             # clip ignored the outliers
    q = quantize_int8_sym(jnp.asarray(acts), s_pct)
    assert int(q.max()) == INT8_QMAX      # outliers saturated, not wrapped
    assert int(q.min()) == -INT8_QMAX
    # in-range values keep sub-LSB error
    inlier = np.abs(acts) < 100 * s_pct
    deq = np.asarray(q, np.float32) * s_pct
    assert np.max(np.abs(deq[inlier] - acts[inlier])) <= 0.5 * s_pct + 1e-7


def test_layer_calibration_absmax_never_saturates_calib_input():
    layer = ConvLayer("c", 10, 10, 3, 4, 3, pad=1)
    w = jax.random.normal(jax.random.key(0), (3, 3, 3, 4)) * 0.1
    x = jax.random.normal(jax.random.key(1), (2, 10, 10, 3)) * 3.0
    lq = calibrate_layer(layer, w, None, x, method="absmax")
    q = quantize_int8_sym(x, lq.in_scale)
    # absmax: the extreme sample maps to ±127 exactly, nothing clips
    assert int(jnp.max(jnp.abs(q))) == INT8_QMAX
    deq_err = jnp.max(jnp.abs(q.astype(jnp.float32) * lq.in_scale - x))
    assert float(deq_err) <= 0.5 * lq.in_scale + 1e-6


# ---------------------------------------------------------------------------
# weight-aware exact-gemm fan bound
# ---------------------------------------------------------------------------

def test_fan_chunk_unchunked_for_ordinary_weights():
    """Bell-shaped weights clear the 127 * max-col-sum(|wq|) < 2^24
    bound even at conv3-sized fans -> whole fan in one gemm."""
    layer = ConvLayer("c3", 13, 13, 256, 32, 3, pad=1)
    w = jax.random.normal(jax.random.key(0), (3, 3, 256, 32)) * 0.05
    lq = quantize_layer(layer, w, None, 0.05, 0.1)
    assert lq.fan_chunk == 256


def test_fan_chunk_conservative_for_saturated_weights():
    """All-qmax weights (the adversarial case the worst-case bound
    guards) trigger EXACT_FP32_FAN chunking — and the kernel stays
    bit-exact against the int32 reference in that regime."""
    from repro.core.quantization import EXACT_FP32_FAN
    from repro.core.schedule import compile_layer, lower_kernel_program, \
        partition_waves
    from repro.kernels.wave_replay_q.ops import wave_replay_q_from_quant
    from repro.kernels.wave_replay_q.ref import quant_layer_ref_from_quant
    layer = ConvLayer("sat", 9, 9, 256, 8, 3, pad=1)
    w = jnp.ones((3, 3, 256, 8))          # quantizes to all-127
    lq = quantize_layer(layer, w, None, 0.05, 4000.0)
    assert lq.fan_chunk == EXACT_FP32_FAN // 9
    from repro.core.decomposition import evaluate
    plan = evaluate(layer, 1, 1, 1, 1)
    kp = lower_kernel_program(partition_waves(compile_layer(layer, plan)))
    xq = jnp.full((1, 9, 9, 256), 127, jnp.int8)   # worst-case acc
    got = wave_replay_q_from_quant(kp, xq, lq)
    ref = quant_layer_ref_from_quant(layer, xq, lq)
    assert jnp.array_equal(got, ref)


# ---------------------------------------------------------------------------
# requantize parameter derivation
# ---------------------------------------------------------------------------

def test_requant_params_reconstruct_scale():
    rng = np.random.default_rng(0)
    ratio = np.exp(rng.uniform(np.log(1e-6), np.log(0.9), 256))
    m, shift, pre = requant_params(ratio, acc_bound=3456 * 127 * 127)
    assert np.all((m >= 64) & (m <= 127))          # normalised mantissa
    assert np.all(shift >= pre)
    approx = m.astype(np.float64) * np.exp2(-shift.astype(np.float64))
    assert np.max(np.abs(approx / ratio - 1)) < 0.008     # 7-bit mantissa


def test_requant_params_rederives_m_at_clipped_shift():
    """Ratios below ~2^-31 cannot carry a normalised mantissa at the
    max shift: m must be re-derived at the clipped shift (denormal)
    instead of keeping the unclipped-mantissa value, which would
    misscale by several x."""
    ratio = np.asarray([7.9e-9, 1e-12, 0.3])
    m, shift, pre = requant_params(ratio, acc_bound=10 ** 6)
    approx = m.astype(np.float64) * np.exp2(-shift.astype(np.float64))
    # denormal regime: graceful degradation, not 4x misscale
    assert abs(approx[0] / ratio[0] - 1) < 0.03
    # unrepresentably tiny: clamps to the smallest positive multiplier
    assert m[1] == 1 and shift[1] == 31
    # ordinary ratios keep the tight 7-bit contract
    assert abs(approx[2] / ratio[2] - 1) < 0.008


def test_requantize_headroom_at_acc_bound():
    """At the exact accumulator bound the int32 requantize neither wraps
    nor deviates from the float computation by more than 1 LSB."""
    acc_bound = 3456 * 127 * 127
    ratio = np.full(4, 127.0 / acc_bound)   # bound maps near qmax
    m, shift, pre = requant_params(ratio, acc_bound)
    acc = jnp.asarray([[acc_bound, -acc_bound, acc_bound - 1, 12345]],
                      jnp.int32)
    got = np.asarray(requantize_i32(acc, jnp.asarray(m), jnp.asarray(shift),
                                    pre), np.int64)[0]
    approx = m[0] * 2.0 ** -float(shift[0])
    want = np.clip(np.round(np.asarray(
        [acc_bound, -acc_bound, acc_bound - 1, 12345], np.float64)
        * approx), -127, 127)
    assert np.max(np.abs(got - want)) <= 1


if hypothesis is not None:
    @hypothesis.given(
        st.integers(-(2 ** 26), 2 ** 26),
        st.floats(1e-6, 0.5),
        st.booleans(),
    )
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_requantize_matches_float_model(acc, ratio, relu):
        """Property: the int32 fixed-point requantize stays within 1 LSB
        of round(acc * (m * 2^-shift)) for any accumulator under the
        bound, with the ReLU clamp honoured."""
        m, shift, pre = requant_params(np.asarray([ratio]), 2 ** 26)
        got = int(requantize_i32(jnp.asarray([acc], jnp.int32),
                                 jnp.asarray(m), jnp.asarray(shift),
                                 pre, relu=relu)[0])
        approx = float(m[0]) * 2.0 ** -float(shift[0])
        lo = 0 if relu else -127
        want = float(np.clip(np.round(acc * approx), lo, 127))
        assert abs(got - want) <= 1
        assert lo <= got <= 127

    @hypothesis.given(st.integers(-(2 ** 30), 2 ** 30), st.integers(0, 12))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_rounding_rshift_rounds_half_up(v, s):
        got = int(rounding_rshift(jnp.asarray(v, jnp.int32), s))
        want = (v + (1 << (s - 1) if s else 0)) >> s
        assert got == want
