"""int8 streaming inference (ISSUE 4): megakernel-vs-int32-reference
bit-exactness on every AlexNet 128 KB plan, end-to-end SNR >= 20 dB per
layer, precision wiring through run_layer_streamed / network_forward_fn
/ StreamingSession, one launch per layer, and the precision-aware
executor cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decomposition import (ALEXNET_STACK, ConvLayer, evaluate,
                                      plan_decomposition)
from repro.core.quantization import (dequantize_int8, quantize_int8_sym)
from repro.core.schedule import compile_layer, compile_network, \
    lower_kernel_program, partition_waves
from repro.core.streaming import (clear_executor_cache, executor_cache_size,
                                  network_forward_fn, network_kernel_programs,
                                  network_operands, run_layer_interpreted,
                                  run_layer_megakernel_q, run_layer_streamed)
from repro.kernels.wave_replay_q import (launch_count, reset_launch_count,
                                         wave_replay_q_from_quant)
from repro.kernels.wave_replay_q.ref import quant_layer_ref_from_quant
from repro.quant import (accuracy_report, calibrate_layer,
                         calibrate_network, quant_reference_acts, snr_db)
from repro.launch.session import StreamingSession


def _weights(layer, key=1, scale=0.05):
    l = layer
    k1, k2 = jax.random.split(jax.random.key(key))
    w = jax.random.normal(
        k1, (l.kernel, l.kernel, l.in_c // l.groups, l.out_c)) * scale
    b = jax.random.normal(k2, (l.out_c,)) * scale
    return w, b


def _alexnet_weights():
    return [( _weights(l, key=i)[0], _weights(l, key=i)[1])
            for i, l in enumerate(ALEXNET_STACK)]


# ---------------------------------------------------------------------------
# Acceptance gate: bit-exact vs the int32 reference on every 128 KB plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layer", ALEXNET_STACK, ids=lambda l: l.name)
def test_int8_megakernel_bit_exact_alexnet(layer):
    """Every ALEXNET_STACK layer under its own 128 KB plan — grouped
    conv2/4/5 (true per-group gemms), conv3's 256-wave partial-sum
    chain, chain coarsening at the default VMEM budget. Integer
    arithmetic end to end, so the comparison is array_equal, not
    tolerance (the ISSUE 4 acceptance gate)."""
    l = layer
    plan = plan_decomposition(l, 128 * 1024)
    x = jax.random.normal(jax.random.key(0), (1, l.in_h, l.in_w, l.in_c))
    w, b = _weights(l)
    lq = calibrate_layer(l, w, b, x)
    xq = quantize_int8_sym(x, lq.in_scale)
    kp = lower_kernel_program(partition_waves(compile_layer(l, plan)))
    got = wave_replay_q_from_quant(kp, xq, lq)
    ref = quant_layer_ref_from_quant(l, xq, lq)
    assert got.dtype == jnp.int8
    assert jnp.array_equal(got, ref)


@pytest.mark.parametrize("vmem_kib", [64, 256, None])
def test_int8_chain_coarsening_stays_bit_exact(vmem_kib):
    """int32 accumulation is associative: 1:1 replay and both coarsened
    chains must produce identical bits, not merely close ones."""
    layer = ConvLayer("chain", 13, 13, 64, 32, 3, pad=1)
    plan = evaluate(layer, 2, 2, 1, 16)       # 16-wave chain
    wprog = partition_waves(compile_layer(layer, plan))
    x = jax.random.normal(jax.random.key(1), (2, 13, 13, 64))
    w, b = _weights(layer)
    lq = calibrate_layer(layer, w, b, x)
    xq = quantize_int8_sym(x, lq.in_scale)
    budget = vmem_kib * 1024 if vmem_kib else None
    kp = lower_kernel_program(wprog, vmem_budget=budget)
    got = wave_replay_q_from_quant(kp, xq, lq)
    ref = quant_layer_ref_from_quant(layer, xq, lq)
    assert jnp.array_equal(got, ref)


def test_int8_ragged_feature_split_bit_exact():
    """out_c_pad > out_c (ragged ungrouped feature split): the padded
    channels carry m=0 requant lanes and crop away — still bit-exact."""
    l = ConvLayer("rag", 12, 12, 8, 10, 3, pad=1)
    plan = evaluate(l, 2, 2, 3, 2)          # fg=4 -> out_c_pad=12
    x = jax.random.normal(jax.random.key(6), (2, 12, 12, 8))
    w, b = _weights(l, scale=0.2)
    lq = calibrate_layer(l, w, b, x)
    xq = quantize_int8_sym(x, lq.in_scale)
    wprog = partition_waves(compile_layer(l, plan))
    assert wprog.program.out_c_pad > l.out_c
    got = wave_replay_q_from_quant(lower_kernel_program(wprog), xq, lq)
    ref = quant_layer_ref_from_quant(l, xq, lq)
    assert jnp.array_equal(got, ref)


def test_int8_fused_relu_pool_epilogue_bit_exact():
    layer = ConvLayer("ep", 20, 20, 8, 16, 3, pad=1, pool=3, pool_stride=2)
    plan = evaluate(layer, 2, 3, 1, 2)
    wprog = partition_waves(compile_layer(layer, plan))
    x = jax.random.normal(jax.random.key(2), (2, 20, 20, 8))
    w, b = _weights(layer, scale=0.2)
    lq = calibrate_layer(layer, w, b, x)
    xq = quantize_int8_sym(x, lq.in_scale)
    kp = lower_kernel_program(wprog, relu=True, fuse_pool=True)
    got = wave_replay_q_from_quant(kp, xq, lq)
    ref = quant_layer_ref_from_quant(layer, xq, lq, relu=True,
                                     fuse_pool=True)
    assert got.shape == (2, layer.pooled_h, layer.pooled_w, 16)
    assert jnp.array_equal(got, ref)
    assert int(got.min()) >= 0                 # ReLU folded into the clip


def test_int8_network_chain_bit_exact_small():
    """End to end through the network path: quantize once, int8 flows
    between layers, final activation equals the int32 reference chain."""
    layers = (ConvLayer("a", 16, 16, 3, 8, 3, pad=1, pool=2),
              ConvLayer("b", 8, 8, 8, 16, 3, pad=1, groups=2))
    weights = [(_weights(l, key=i, scale=0.2)[0],
                jnp.full((l.out_c,), 0.1)) for i, l in enumerate(layers)]
    x = jax.random.normal(jax.random.key(5), (2, 16, 16, 3))
    qnet = calibrate_network(layers, weights, x)
    plans = [plan_decomposition(l, 64 * 1024) for l in layers]
    programs = compile_network(layers, plans)
    fwd = jax.jit(network_forward_fn(programs, mode="megakernel",
                                     precision="int8", qnet=qnet,
                                     dequantize=False))
    ops = network_operands(programs, "megakernel")
    got = fwd(x, qnet.device_weights(), ops)
    ref = quant_reference_acts(qnet, x)[-1]
    assert got.dtype == jnp.int8
    assert jnp.array_equal(got, ref)


# ---------------------------------------------------------------------------
# Accuracy: the 20 dB per-layer SNR gate on the AlexNet stack
# ---------------------------------------------------------------------------

def test_alexnet_int8_snr_at_least_20db_per_layer():
    weights = _alexnet_weights()
    calib = jax.random.normal(jax.random.key(10), (2, 227, 227, 3))
    qnet = calibrate_network(ALEXNET_STACK, weights, calib)
    x = jax.random.normal(jax.random.key(11), (1, 227, 227, 3))
    report = accuracy_report(qnet, weights, x, runner="ref")
    assert len(report) == len(ALEXNET_STACK)
    for rec in report:
        assert rec["snr_db"] >= 20.0, rec      # the acceptance bar


def test_megakernel_runner_matches_ref_runner():
    """The accuracy harness's two runners are the bit-exactness gate
    from another angle: identical SNR because identical activations."""
    layers = ALEXNET_STACK[:2]
    weights = _alexnet_weights()[:2]
    x = jax.random.normal(jax.random.key(12), (1, 227, 227, 3))
    qnet = calibrate_network(layers, weights, x)
    ref_rep = accuracy_report(qnet, weights, x, runner="ref")
    mk_rep = accuracy_report(qnet, weights, x, runner="megakernel")
    assert [r["snr_db"] for r in ref_rep] == [r["snr_db"] for r in mk_rep]


# ---------------------------------------------------------------------------
# Wiring: run_layer_streamed / session / serve-level behaviour
# ---------------------------------------------------------------------------

def test_run_layer_streamed_int8_roundtrip():
    """The layer-level entry takes fp32 in, fp32 out; with an explicit
    LayerQuant it matches dequantize(int32-ref) bit for bit, and
    approximates the float interpreter to quantization accuracy."""
    layer = ConvLayer("r", 14, 14, 6, 10, 3, pad=1)
    plan = evaluate(layer, 2, 2, 1, 2)
    x = jax.random.normal(jax.random.key(3), (2, 14, 14, 6))
    w, b = _weights(layer, scale=0.2)
    lq = calibrate_layer(layer, w, b, x)
    got = run_layer_streamed(layer, plan, x, w, b, mode="megakernel",
                             precision="int8", quant=lq)
    xq = quantize_int8_sym(x, lq.in_scale)
    ref = dequantize_int8(quant_layer_ref_from_quant(layer, xq, lq),
                          lq.out_scale)
    assert jnp.array_equal(got, ref)
    float_ref = run_layer_interpreted(layer, plan, x, w, b)
    assert snr_db(float_ref, got) > 25.0


def test_run_layer_streamed_int8_calibrates_on_the_fly():
    layer = ConvLayer("f", 12, 12, 4, 8, 3, pad=1)
    plan = evaluate(layer, 1, 2, 1, 1)
    x = jax.random.normal(jax.random.key(4), (1, 12, 12, 4))
    w, b = _weights(layer, scale=0.3)
    got = run_layer_streamed(layer, plan, x, w, b, mode="megakernel",
                             precision="int8")
    ref = run_layer_interpreted(layer, plan, x, w, b)
    assert snr_db(ref, got) > 25.0


def test_int8_requires_megakernel_mode():
    layer = ConvLayer("e", 8, 8, 3, 4, 3, pad=1)
    plan = evaluate(layer, 1, 1, 1, 1)
    x = jax.random.normal(jax.random.key(0), (1, 8, 8, 3))
    w, b = _weights(layer)
    for mode in ("wave", "scan", "interpret"):
        with pytest.raises(ValueError, match="quantized megakernel"):
            run_layer_streamed(layer, plan, x, w, b, mode=mode,
                               precision="int8")
    with pytest.raises(ValueError, match="unknown precision"):
        run_layer_streamed(layer, plan, x, w, b, precision="int4")


def test_network_forward_int8_validates_inputs():
    layers = (ConvLayer("v", 8, 8, 3, 4, 3, pad=1),)
    programs = compile_network(layers, [plan_decomposition(layers[0],
                                                           64 * 1024)])
    with pytest.raises(ValueError, match="calibrated QuantizedNetwork"):
        network_forward_fn(programs, mode="megakernel", precision="int8")
    with pytest.raises(ValueError, match="quantized megakernel"):
        network_forward_fn(programs, mode="wave", precision="int8",
                           qnet=object())


def test_session_int8_serves_and_compiles_once():
    layers = (ConvLayer("a", 16, 16, 3, 8, 3, pad=1, pool=2),
              ConvLayer("b", 8, 8, 8, 16, 3, pad=1, groups=2))
    weights = [(_weights(l, key=i, scale=0.2)[0],
                jnp.full((l.out_c,), 0.1)) for i, l in enumerate(layers)]
    calib = jax.random.normal(jax.random.key(6), (2, 16, 16, 3))
    qnet = calibrate_network(layers, weights, calib)
    sess = StreamingSession.for_network(layers, None, sram_budget=64 * 1024,
                                        max_batch=2, mode="megakernel",
                                        precision="int8", qnet=qnet)
    assert sess.precision == "int8"
    x = jax.random.normal(jax.random.key(7), (2, 16, 16, 3))
    reset_launch_count()
    y = sess.run_batch(jnp.array(x))
    assert launch_count() == len(layers)      # one pallas_call per layer
    assert sess.compile_count == 1
    # micro-batch queue shares the same executable
    t0 = sess.submit(x[0])
    out0 = sess.result(t0)
    assert sess.compile_count == 1
    assert out0.shape == y[0].shape
    # output matches the dequantized int32 reference chain
    ref = dequantize_int8(quant_reference_acts(qnet, x)[-1],
                          qnet.out_scale)
    assert jnp.array_equal(y, ref)


def test_session_int8_requires_qnet_and_matching_stack():
    layers = (ConvLayer("a", 8, 8, 3, 4, 3, pad=1),)
    with pytest.raises(ValueError, match="calibrated qnet"):
        StreamingSession.for_network(layers, None, sram_budget=64 * 1024,
                                     mode="megakernel", precision="int8")
    other = (ConvLayer("other", 8, 8, 3, 4, 3, pad=1, pool=2),)
    w = [(_weights(other[0])[0], None)]
    qnet = calibrate_network(
        other, w, jax.random.normal(jax.random.key(0), (1, 8, 8, 3)))
    with pytest.raises(ValueError, match="different layer stack"):
        StreamingSession.for_network(layers, None, sram_budget=64 * 1024,
                                     mode="megakernel", precision="int8",
                                     qnet=qnet)


# ---------------------------------------------------------------------------
# The executor-cache precision fix (ISSUE 4 satellite)
# ---------------------------------------------------------------------------

def test_executor_cache_keeps_fp32_and_int8_apart():
    """Same layer, same plan, same batch shape, same fp32 input dtype:
    the fp32 and int8 megakernel executables must occupy distinct cache
    slots and keep answering correctly when interleaved."""
    layer = ConvLayer("k", 12, 12, 4, 8, 3, pad=1)
    plan = evaluate(layer, 2, 2, 1, 2)
    x = jax.random.normal(jax.random.key(8), (1, 12, 12, 4))
    w, b = _weights(layer, scale=0.2)
    lq = calibrate_layer(layer, w, b, x)
    clear_executor_cache()
    y_f1 = run_layer_streamed(layer, plan, x, w, b, mode="megakernel")
    n_after_fp32 = executor_cache_size()
    y_q1 = run_layer_streamed(layer, plan, x, w, b, mode="megakernel",
                              precision="int8", quant=lq)
    assert executor_cache_size() == n_after_fp32 + 1   # distinct slot
    # interleave: each precision must keep hitting its own executable
    y_f2 = run_layer_streamed(layer, plan, x, w, b, mode="megakernel")
    y_q2 = run_layer_streamed(layer, plan, x, w, b, mode="megakernel",
                              precision="int8", quant=lq)
    assert executor_cache_size() == n_after_fp32 + 1   # cache hits only
    assert jnp.array_equal(y_f1, y_f2)
    assert jnp.array_equal(y_q1, y_q2)
    # and the answers are genuinely different paths (quantized vs not)
    assert not jnp.array_equal(y_f1, y_q1)


def test_int8_cache_distinguishes_scales():
    """Two calibrations of the same geometry bake different scales —
    they must not serve each other's executables."""
    layer = ConvLayer("s", 10, 10, 4, 6, 3, pad=1)
    plan = evaluate(layer, 1, 1, 1, 1)
    x = jax.random.normal(jax.random.key(9), (1, 10, 10, 4))
    w, b = _weights(layer, scale=0.2)
    lq1 = calibrate_layer(layer, w, b, x)
    lq2 = calibrate_layer(layer, w, b, x * 4.0)      # wider scales
    wprog = partition_waves(compile_layer(layer, plan))
    y1 = run_layer_megakernel_q(wprog, x, lq1)
    y2 = run_layer_megakernel_q(wprog, x, lq2)
    xq1 = quantize_int8_sym(x, lq1.in_scale)
    xq2 = quantize_int8_sym(x, lq2.in_scale)
    r1 = dequantize_int8(quant_layer_ref_from_quant(layer, xq1, lq1),
                         lq1.out_scale)
    r2 = dequantize_int8(quant_layer_ref_from_quant(layer, xq2, lq2),
                         lq2.out_scale)
    assert jnp.array_equal(y1, r1)
    assert jnp.array_equal(y2, r2)


# ---------------------------------------------------------------------------
# Schedule reuse: quantization must not perturb the planner
# ---------------------------------------------------------------------------

def test_int8_reuses_fp32_kernel_programs_and_tables():
    layers = ALEXNET_STACK[:2]
    plans = [plan_decomposition(l, 128 * 1024) for l in layers]
    programs = compile_network(layers, plans)
    kprogs = network_kernel_programs(programs)
    ops_f = network_operands(programs, "megakernel")
    # the int8 forward consumes the SAME operand tables object-for-object
    # (network_operands has no precision parameter at all), and the same
    # KernelProgram geometries
    for kp, ops in zip(kprogs, ops_f):
        assert ops.shape == (kp.n_chain, kp.n_tiles, 8)
        assert np.array_equal(np.asarray(ops), kp.operand_table())


# ---------------------------------------------------------------------------
# int8 residual epilogue (ISSUE 5): requantize -> add -> ReLU-clip,
# bit-exact against the int32 reference with the same op order
# ---------------------------------------------------------------------------

def test_q_megakernel_residual_bit_exact():
    from repro.core.quantization import quantize_int8_sym
    layer = ConvLayer("qres", 12, 12, 8, 8, 3, pad=1)
    plan = evaluate(layer, 2, 2, 1, 2)
    kp = lower_kernel_program(partition_waves(compile_layer(layer, plan)),
                              relu=True, residual=True, vmem_budget=None)
    x = jax.random.normal(jax.random.key(0), (2, 12, 12, 8))
    w = jax.random.normal(jax.random.key(1), (3, 3, 8, 8)) * 0.2
    b = jax.random.normal(jax.random.key(2), (8,)) * 0.1
    q = calibrate_layer(layer, w, b, x)
    xq = quantize_int8_sym(x, q.in_scale)
    rq = quantize_int8_sym(
        jax.random.normal(jax.random.key(3), (2, 12, 12, 8)), q.out_scale)
    got = wave_replay_q_from_quant(kp, xq, q, residual=rq)
    ref = quant_layer_ref_from_quant(layer, xq, q, relu=True, residual=rq)
    assert jnp.array_equal(got, ref), "int8 residual epilogue != reference"


def test_residual_add_i8_clips_and_folds_relu():
    from repro.kernels.wave_replay_q.kernel import residual_add_i8
    a = jnp.array([[100, -100, 127, -127]], jnp.int8)
    r = jnp.array([[100, -100, 127, 127]], jnp.int8)
    s = residual_add_i8(a, r, relu=False)
    assert s.tolist() == [[127, -127, 127, 0]]       # saturating int8
    s_relu = residual_add_i8(a, r, relu=True)
    assert s_relu.tolist() == [[127, 0, 127, 0]]     # ReLU folds the clip
