"""Property tests for fixed-point quantization (paper's 16-bit CU
datapath)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import (QFormat, calibrate_frac_bits,
                                     dequantize, fixed_point_matmul,
                                     quantize)

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # dev-only dependency (requirements.txt)
    hypothesis = None


def test_quantize_saturates():
    q = QFormat(8, 4)
    x = jnp.asarray([1e6, -1e6], jnp.float32)
    xq = quantize(x, q)
    assert int(xq[0]) == q.qmax and int(xq[1]) == q.qmin


def test_requantize_shift():
    qa = QFormat(16, 8)
    qb = QFormat(16, 8)
    qo = QFormat(16, 8)
    a = jnp.asarray([[1.5]], jnp.float32)
    b = jnp.asarray([[2.25]], jnp.float32)
    out = fixed_point_matmul(quantize(a, qa), quantize(b, qb), qa, qb, qo)
    assert abs(dequantize(out, qo)[0, 0] - 1.5 * 2.25) <= qo.lsb


if hypothesis is not None:
    @hypothesis.given(
        st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                 max_size=64),
        st.sampled_from([8, 16]),
    )
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_roundtrip_error_bounded(vals, bits):
        x = jnp.asarray(vals, jnp.float32)
        q = calibrate_frac_bits(x, bits)
        xq = quantize(x, q)
        xd = dequantize(xq, q)
        # calibration guarantees no saturation -> error <= 0.5 LSB

        assert float(jnp.max(jnp.abs(xd - x))) <= 0.5 * q.lsb + 1e-7

    @hypothesis.given(st.integers(4, 24), st.integers(4, 24),
                      st.integers(4, 24))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_fixed_point_matmul_matches_float(m, k, n):
        rng = np.random.RandomState(m * 31 + k * 7 + n)
        a = rng.randn(m, k).astype(np.float32)
        b = rng.randn(k, n).astype(np.float32)
        qa = calibrate_frac_bits(jnp.asarray(a), 16)
        qb = calibrate_frac_bits(jnp.asarray(b), 16)
        got = fixed_point_matmul(quantize(jnp.asarray(a), qa),
                                 quantize(jnp.asarray(b), qb), qa, qb)
        ref = a @ b
        # error accumulates ~ k * (lsb_a * |b| + lsb_b * |a|)
        tol = k * (qa.lsb * np.abs(b).max() + qb.lsb * np.abs(a).max())
        assert float(jnp.max(jnp.abs(got - ref))) <= tol + 1e-5
else:
    def test_property_cases_need_hypothesis():
        pytest.importorskip("hypothesis")  # skips, visibly
