"""RG-LRU: associative scan == sequential step; causal conv1d properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models.module import init_params
from repro.models.recurrent import (_rglru_coeffs, apply_rglru_block,
                                    causal_conv1d, init_rglru_cache,
                                    rglru_defs, rglru_scan, rglru_step)


def _cfg():
    return dataclasses.replace(reduced_config("recurrentgemma_2b"),
                               compute_dtype="float32")


def test_conv1d_matches_numpy():
    B, S, D, W = 2, 10, 4, 4
    x = jax.random.normal(jax.random.key(0), (B, S, D))
    w = jax.random.normal(jax.random.key(1), (W, D))
    b = jax.random.normal(jax.random.key(2), (D,))
    y, _ = causal_conv1d(w, b, x)
    xp = np.pad(np.asarray(x), ((0, 0), (W - 1, 0), (0, 0)))
    ref = np.zeros((B, S, D))
    for t in range(S):
        for j in range(W):
            ref[:, t] += xp[:, t + j] * np.asarray(w[j])
    ref += np.asarray(b)
    assert np.max(np.abs(np.asarray(y) - ref)) < 1e-5


def test_conv1d_streaming_state_matches_full():
    """Decode-style chunked conv (state carried) == full-sequence conv —
    the 1-D line buffer invariant."""
    B, S, D, W = 2, 12, 4, 4
    x = jax.random.normal(jax.random.key(0), (B, S, D))
    w = jax.random.normal(jax.random.key(1), (W, D))
    b = jnp.zeros((D,))
    full, _ = causal_conv1d(w, b, x)
    state = jnp.zeros((B, W - 1, D))
    outs = []
    for t in range(S):
        y, state = causal_conv1d(w, b, x[:, t:t + 1], state=state)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(got - full)) < 1e-5


def test_rglru_scan_matches_step_by_step():
    cfg = _cfg()
    p = init_params(rglru_defs(cfg), jax.random.key(0))
    B, S = 2, 9
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.recurrent.d_rnn))
    y_par, h_last = rglru_scan(p, x)
    h = jnp.zeros((B, cfg.recurrent.d_rnn), jnp.float32)
    outs = []
    for t in range(S):
        y, h = rglru_step(p, x[:, t:t + 1], h)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(y_par - y_seq)) < 1e-4
    assert jnp.max(jnp.abs(h_last - h)) < 1e-4


def test_rglru_decay_is_contractive():
    """|a_t| < 1 always — the recurrence cannot blow up."""
    cfg = _cfg()
    p = init_params(rglru_defs(cfg), jax.random.key(0))
    x = 10 * jax.random.normal(jax.random.key(1), (2, 7, cfg.recurrent.d_rnn))
    a, b = _rglru_coeffs(p, x)
    # a in (0, 1]; == 1.0 only when the gate saturates to fully-open
    assert float(jnp.max(a)) <= 1.0
    assert float(jnp.min(a)) > 0.0
    assert float(jnp.mean(a)) < 1.0


def test_rglru_block_cache_consistency():
    cfg = _cfg()
    p = init_params(rglru_defs(cfg), jax.random.key(0))
    B, S = 2, 8
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
    full, _ = apply_rglru_block(cfg, p, x)
    cache = init_rglru_cache(cfg, B, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = apply_rglru_block(cfg, p, x[:, t:t + 1], cache=cache)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(got - full)) < 1e-3
