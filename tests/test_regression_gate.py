"""CI benchmark-regression gate (benchmarks/regression_gate): the
direct ratchet rules — grouped/block-diagonal speedup (ISSUE 10),
int8/fp32, batched throughput, tuned-vs-fixed — plus the modelled
DRAM-traffic / launch-count no-growth checks, presence rules, and the
opt-in --absolute same-machine time comparison. The PR-3 share-
normalised slowdown rule is retired (ISSUE 10) and its absence is
pinned here too."""
import importlib.util
import pathlib

import pytest

_GATE = pathlib.Path(__file__).resolve().parents[1] / "benchmarks" \
    / "regression_gate.py"
spec = importlib.util.spec_from_file_location("regression_gate", _GATE)
gate = importlib.util.module_from_spec(spec)
spec.loader.exec_module(gate)


def _payload(direct_us, wave_us, mega_us, traffic=1000):
    return {"records": [
        {"name": "streaming_alexnet_direct", "us_per_call": direct_us,
         "meta": {}},
        {"name": "streaming_alexnet_wave", "us_per_call": wave_us,
         "meta": {"dram_traffic_bytes": traffic}},
        {"name": "streaming_alexnet_megakernel", "us_per_call": mega_us,
         "meta": {"dram_traffic_bytes": traffic}},
        {"name": "streaming_alexnet_interpreted", "us_per_call": 1e6,
         "meta": {}},
    ]}


def test_gate_passes_identical_runs():
    base = _payload(100, 300, 200)
    assert gate.compare(base, base) == []


def test_gate_is_machine_portable():
    """A uniformly 3x slower machine trips nothing: every default rule
    is a same-run ratio, a modelled counter, or row presence."""
    base = _payload(100, 300, 200)
    cur = _payload(300, 900, 600)
    assert gate.compare(base, cur) == []


def test_gate_share_rule_is_retired():
    """ISSUE 10: a single executor row slowing down no longer fails the
    default (cross-machine) gate — the PR-3 share-normalised rule is
    gone; raw-time comparison survives only behind --absolute."""
    base = _payload(100, 300, 200)
    cur = _payload(100, 300, 300)       # megakernel alone 1.5x slower
    assert gate.compare(base, cur) == []
    fails = gate.compare(base, cur, absolute=True)
    assert len(fails) == 1 and "megakernel" in fails[0] \
        and "us" in fails[0]


def test_gate_fails_on_traffic_growth():
    base = _payload(100, 300, 200, traffic=1000)
    cur = _payload(100, 300, 200, traffic=1200)
    fails = gate.compare(base, cur)
    assert len(fails) == 2              # wave + megakernel rows grew
    assert all("DRAM traffic" in f for f in fails)


def test_gate_absolute_mode():
    base = _payload(100, 300, 200)
    cur = _payload(300, 900, 600)       # slower machine
    fails = gate.compare(base, cur, absolute=True)
    assert len(fails) == 2              # wave + megakernel (direct skipped)


def test_gate_skips_noisy_and_missing_records():
    base = _payload(100, 300, 200)
    cur = {"records": [r for r in _payload(100, 300, 9000)["records"]
                       if r["name"] != "streaming_alexnet_megakernel"]}
    # interpreted is always skipped; missing rows don't crash the gate
    assert gate.compare(base, cur) == []


def test_merge_min_takes_best_of_runs():
    """Contention poisons whole runs; the merge takes each record's best
    run, so one clean run per mode is enough to clear the gate."""
    run1 = _payload(100, 900, 200)      # wave poisoned
    run2 = _payload(100, 300, 600)      # megakernel poisoned
    merged = gate.merge_min([run1, run2])
    us = {r["name"]: r["us_per_call"] for r in merged["records"]}
    assert us["streaming_alexnet_wave"] == 300
    assert us["streaming_alexnet_megakernel"] == 200
    assert gate.compare(_payload(100, 300, 200), merged) == []


def _payload_int8(mega_us, int8_us):
    p = _payload(100, 300, mega_us)
    p["records"].append(
        {"name": "streaming_alexnet_megakernel_int8",
         "us_per_call": int8_us, "meta": {"dram_traffic_bytes": 500}})
    return p


def test_gate_int8_speedup_on_baseline():
    """The committed int8/fp32 ratio is the acceptance artifact: a
    baseline below the required speedup fails regardless of the
    current run."""
    good = _payload_int8(240, 200)          # 1.2x exactly
    assert gate.compare(good, good) == []
    bad = _payload_int8(210, 200)           # 1.05x
    fails = gate.compare(bad, bad)
    assert any("committed baseline int8 speedup" in f for f in fails)


def test_gate_int8_speedup_on_current_run_with_slack():
    base = _payload_int8(300, 200)          # 1.5x committed
    # current at 1.08x: above the 1.2/(1+0.2) = 1.0 floor -> noise, pass
    ok = gate.compare(base, _payload_int8(216, 200))
    assert ok == []
    # current below the floor -> real regression
    fails = gate.compare(base, _payload_int8(190, 200))
    assert any("measured int8 speedup" in f for f in fails)
    # a stricter requirement tightens both checks
    fails = gate.compare(base, _payload_int8(216, 200), int8_speedup=2.0)
    assert any("measured int8 speedup" in f for f in fails)


def test_gate_int8_row_gated_through_ratio_not_time():
    """The int8 row's wall-clock matters only through the same-run
    int8/fp32 ratio: a slower int8 row fails once the ratio drops below
    the slacked floor, not through any per-row time rule."""
    base = _payload_int8(300, 200)
    # 300/290 = 1.03x: above the 1.2/(1+0.2) = 1.0 floor -> passes
    assert gate.compare(base, _payload_int8(300, 290)) == []
    # 300/320 = 0.94x: below the floor -> the ratio rule fires
    fails = gate.compare(base, _payload_int8(300, 320))
    assert any("measured int8 speedup" in f for f in fails)


def test_gate_fails_when_current_run_drops_int8_row():
    """A baseline with the int8 row pins the measurement: a current run
    that stopped emitting it fails instead of silently skipping the
    speedup check."""
    base = _payload_int8(300, 200)
    cur = {"records": [r for r in _payload_int8(300, 200)["records"]
                       if not r["name"].endswith("_int8")]}
    fails = gate.compare(base, cur)
    assert any("missing" in f for f in fails)


def test_gate_without_int8_rows_is_unchanged():
    """Baselines predating the int8 path never trip the ratio gate,
    and the new row is simply ignored by the share checks (it is not in
    the baseline's shared set)."""
    base = _payload(100, 300, 200)
    assert gate.compare(base, _payload_int8(200, 999)) == []


def test_gate_cli(tmp_path):
    import json
    b = tmp_path / "base.json"
    c = tmp_path / "cur.json"
    b.write_text(json.dumps(_payload(100, 300, 200)))
    c.write_text(json.dumps(_payload(100, 300, 400)))
    with pytest.raises(SystemExit):
        gate.main(["--baseline", str(b), "--current", str(c),
                   "--absolute"])
    gate.main(["--baseline", str(b), "--current", str(c)])
    gate.main(["--baseline", str(b), "--current", str(b)])


# ---------------------------------------------------------------------------
# Per-network rows (ISSUE 5): baseline-present + traffic no-growth
# ---------------------------------------------------------------------------

def _payload_networks(vgg_traffic=500, res_traffic=400, include=True):
    p = _payload(100, 300, 200)
    if include:
        p["records"] += [
            {"name": "streaming_vgg16_wave", "us_per_call": 50,
             "meta": {"dram_traffic_bytes": vgg_traffic}},
            {"name": "streaming_resnet18_wave", "us_per_call": 40,
             "meta": {"dram_traffic_bytes": res_traffic}},
        ]
    return p


def test_gate_network_rows_pass_identical():
    base = _payload_networks()
    assert gate.compare(base, base) == []


def test_gate_fails_when_network_row_goes_missing():
    base = _payload_networks()
    cur = _payload_networks(include=False)
    fails = gate.compare(base, cur)
    assert len(fails) == 2
    assert all("per-network row" in f for f in fails)


def test_gate_fails_on_network_traffic_growth():
    base = _payload_networks(res_traffic=400)
    cur = _payload_networks(res_traffic=450)
    fails = gate.compare(base, cur)
    assert len(fails) == 1 and "resnet18" in fails[0] \
        and "DRAM traffic" in fails[0]


def test_gate_network_rows_are_not_time_gated():
    """Reduced-scale few-rep network rows: a 10x slower time alone must
    not fail the gate (presence + traffic are the per-network rules)."""
    base = _payload_networks()
    cur = _payload_networks()
    for r in cur["records"]:
        if r["name"].startswith(("streaming_vgg16", "streaming_resnet18")):
            r["us_per_call"] *= 10
    assert gate.compare(base, cur) == []


def test_gate_baseline_without_network_rows_accepts_new_rows():
    base = _payload(100, 300, 200)
    assert gate.compare(base, _payload_networks()) == []


# ---------------------------------------------------------------------------
# Launches-no-growth (ISSUE 6): fused-chain launch counts must not grow
# ---------------------------------------------------------------------------

def _payload_graphkernel(launches=1, net_launches=2, traffic=800):
    p = _payload(100, 300, 200)
    p["records"] += [
        {"name": "streaming_alexnet_graphkernel", "us_per_call": 150,
         "meta": {"launches": launches, "dram_traffic_bytes": traffic}},
        {"name": "streaming_resnet18_graphkernel", "us_per_call": 40,
         "meta": {"launches": net_launches, "dram_traffic_bytes": 400}},
    ]
    return p


def test_gate_launches_pass_identical():
    base = _payload_graphkernel()
    assert gate.compare(base, base) == []


def test_gate_fails_on_launch_growth_gated_row():
    """The alexnet graphkernel row is launch-gated: a chain splitting
    1 -> 2 launches fails even at the same speed."""
    base = _payload_graphkernel(launches=1)
    cur = _payload_graphkernel(launches=2)
    fails = gate.compare(base, cur)
    assert any("streaming_alexnet_graphkernel" in f
               and "launches grew 1 -> 2" in f for f in fails)


def test_gate_graphkernel_rows_are_not_time_gated():
    """Interpret-mode CI pays emulation cost, not launch overhead:
    graphkernel wall-clock alone must never fail the gate, and the big
    noisy row must not destabilise its group's share sums."""
    base = _payload_graphkernel()
    cur = _payload_graphkernel()
    for r in cur["records"]:
        if r["name"].endswith("_graphkernel"):
            r["us_per_call"] *= 10
    assert gate.compare(base, cur) == []


def test_gate_fails_when_graphkernel_row_goes_missing():
    base = _payload_graphkernel()
    cur = _payload_graphkernel()
    cur["records"] = [r for r in cur["records"]
                      if r["name"] != "streaming_alexnet_graphkernel"]
    fails = gate.compare(base, cur)
    assert len(fails) == 1 and "streaming_alexnet_graphkernel" in fails[0] \
        and "fused-chain path" in fails[0]


def test_gate_fails_on_graphkernel_traffic_growth():
    base = _payload_graphkernel(traffic=800)
    cur = _payload_graphkernel(traffic=900)
    fails = gate.compare(base, cur)
    assert len(fails) == 1 and "streaming_alexnet_graphkernel" in fails[0] \
        and "DRAM traffic" in fails[0]


def test_gate_fails_on_launch_growth_network_row():
    base = _payload_graphkernel(net_launches=2)
    cur = _payload_graphkernel(net_launches=5)
    fails = gate.compare(base, cur)
    assert len(fails) == 1 and "resnet18_graphkernel" in fails[0] \
        and "chain-fusion regression" in fails[0]


def test_gate_launch_shrink_is_fine():
    """Fewer launches (better fusion) never fails."""
    base = _payload_graphkernel(launches=2, net_launches=5)
    cur = _payload_graphkernel(launches=1, net_launches=2)
    assert gate.compare(base, cur) == []


def test_gate_rows_without_launches_meta_unaffected():
    base = _payload_graphkernel()
    for r in base["records"]:
        r["meta"].pop("launches", None)
    assert gate.compare(base, _payload_graphkernel()) == []


# ---------------------------------------------------------------------------
# Zero-degradation rule (ISSUE 7): clean bench runs must report zero
# fallback-runtime degradation events
# ---------------------------------------------------------------------------

def _payload_degradation(events):
    p = _payload(100, 300, 200)
    p["records"].append(
        {"name": "streaming_alexnet_graphkernel", "us_per_call": 500,
         "meta": {"launches": 1, "dram_traffic_bytes": 500,
                  "degradation_events": events}})
    return p


def test_gate_zero_degradation_passes():
    base = _payload_degradation(0)
    assert gate.compare(base, _payload_degradation(0)) == []


def test_gate_fails_on_degradation_events_in_current_run():
    base = _payload_degradation(0)
    fails = gate.compare(base, _payload_degradation(2))
    assert len(fails) == 1
    assert "2 degradation event(s)" in fails[0]
    assert "graphkernel" in fails[0]


def test_gate_degradation_rule_covers_rows_missing_from_baseline():
    """The rule gates the CURRENT run only — a new row (absent from an
    old baseline) with degradations still fails."""
    base = _payload(100, 300, 200)       # baseline predates the meta key
    fails = gate.compare(base, _payload_degradation(1))
    assert any("degradation" in f for f in fails)


def test_gate_rows_without_degradation_meta_unaffected():
    """Old measurement files (no degradation_events key) keep passing."""
    base = _payload_degradation(0)
    cur = _payload_degradation(0)
    for r in cur["records"]:
        r["meta"].pop("degradation_events", None)
    assert gate.compare(base, cur) == []


# ---------------------------------------------------------------------------
# Batch-axis throughput ratchet (ISSUE 8): *_batch<B> curve families
# ---------------------------------------------------------------------------

def _batch_row(name, batch, us):
    return {"name": f"{name}_batch{batch}", "us_per_call": us,
            "meta": {"batch": batch, "us_per_image": us / batch,
                     "throughput_imgs_s": batch / (us * 1e-6)}}


def _payload_batches(us1=1000, us16=None, us64=None, include=True,
                     fam="streaming_facedet_wave"):
    """One facedet wave curve family; default gain 16/64 rows at 5x."""
    p = _payload(100, 300, 200)
    if include:
        p["records"].append(_batch_row(fam, 1, us1))
        if us16 is not None:
            p["records"].append(_batch_row(fam, 16, us16))
        if us64 is not None:
            p["records"].append(_batch_row(fam, 64, us64))
    return p


def test_gate_batch_curve_passes_at_required_gain():
    # batch=64 at 12800us -> 5000 img/s vs 1000 img/s at batch=1: 5x
    base = _payload_batches(1000, us16=4000, us64=12800)
    assert gate.compare(base, base) == []


def test_gate_fails_on_weak_committed_batch_gain():
    """Acceptance: the committed curve itself must show >= 4x."""
    base = _payload_batches(1000, us16=8000, us64=32000)   # 2x only
    fails = gate.compare(base, base)
    assert any("committed batched throughput gain 2.00x" in f
               for f in fails)


def test_gate_batch_rule_is_per_network_best_family():
    """The ratchet scores each NETWORK on its best executor family:
    a megakernel curve that saturates early (VMEM-clamped block) is
    fine while the wave curve scales."""
    base = _payload_batches(1000, us16=8000, us64=32000,   # mega: 2x
                            fam="streaming_facedet_megakernel")
    base["records"] += _payload_batches(
        1000, us16=3200, us64=12800)["records"][-3:]       # wave: 5x
    assert gate.compare(base, base) == []


def test_gate_batch_rule_takes_best_batched_row():
    """The rule is max over B >= 16 — one strong point clears it even
    if a bigger batch saturates."""
    # batch16: 16/3200us = 5x; batch64 flat at 1x-per-image
    base = _payload_batches(1000, us16=3200, us64=64000)
    assert gate.compare(base, base) == []


def test_gate_batch_current_run_gets_threshold_slack():
    base = _payload_batches(1000, us16=4000, us64=12800)   # 5x committed
    # current at 3.5x: above the 4/(1+0.2) = 3.33 floor -> noise
    ok = gate.compare(base, _payload_batches(1000, us16=4571, us64=18286))
    assert ok == []
    # current at 2x -> real regression
    fails = gate.compare(base, _payload_batches(1000, us16=8000,
                                                us64=32000))
    assert any("measured batched throughput gain" in f for f in fails)


def test_gate_fails_when_batch_curve_goes_missing():
    base = _payload_batches(1000, us16=4000, us64=12800)
    fails = gate.compare(base, _payload_batches(include=False))
    assert any("batch curves present in baseline but incomplete" in f
               for f in fails)
    # dropping just the batched end also disarms -> fail
    fails = gate.compare(base, _payload_batches(1000))
    assert any("incomplete" in f for f in fails)


def test_gate_incomplete_baseline_curve_is_not_gated():
    """A baseline with only the batch=1 anchor (or only batched rows)
    has no curve to ratchet — no failure, like pre-ISSUE-8 baselines."""
    base = _payload_batches(1000)                  # anchor only
    assert gate.compare(base, base) == []
    base = _payload_batches(include=False)
    assert gate.compare(base, _payload_batches(1000, us16=4000)) == []


def test_gate_batch_speedup_knob():
    base = _payload_batches(1000, us16=3200)       # 5x
    fails = gate.compare(base, base, batch_speedup=6.0)
    assert any("required 6.00x" in f for f in fails)
    assert gate.compare(base, base, batch_speedup=4.0) == []


def test_gate_batch_rows_are_not_share_gated():
    """Curve rows live outside the share groups: a slower curve row in
    isolation only matters through its own family's ratchet."""
    base = _payload_batches(1000, us16=4000, us64=12800)
    cur = _payload_batches(900, us16=3600, us64=11520)     # same 5x gain
    assert gate.compare(base, cur) == []


def test_gate_batch_throughput_meta_optional():
    """_throughput falls back to batch/us when the explicit meta field
    is absent (hand-built or older measurement files)."""
    base = _payload_batches(1000, us16=4000, us64=12800)
    cur = _payload_batches(1000, us16=4000, us64=12800)
    for r in cur["records"]:
        r.get("meta", {}).pop("throughput_imgs_s", None)
    assert gate.compare(base, cur) == []


# ---------------------------------------------------------------------------
# mode="auto" ratchet (ISSUE 8): tuned plan vs best fixed mode
# ---------------------------------------------------------------------------

def _payload_auto(auto_us, wave_us=300, mega_us=200):
    p = _payload(100, wave_us, mega_us)
    p["records"].append(
        {"name": "streaming_alexnet_auto", "us_per_call": auto_us,
         "meta": {"batch": 1, "node_modes": {"c1": "wave"}}})
    return p


def test_gate_auto_beats_best_fixed_passes():
    base = _payload_auto(180)                      # beats mega's 200
    assert gate.compare(base, base) == []
    tie = _payload_auto(200)                       # ties are fine
    assert gate.compare(tie, tie) == []


def test_gate_fails_on_committed_auto_losing_to_fixed():
    """Acceptance: the committed tuned plan must not lose to the best
    fixed-mode row — strictly, no slack on the artifact of record."""
    base = _payload_auto(210)
    fails = gate.compare(base, base)
    assert any("committed tuned plan 210us slower" in f for f in fails)


def test_gate_auto_current_run_gets_threshold_slack():
    base = _payload_auto(180)
    # current auto 15% over best fixed: within the 20% slack
    assert gate.compare(base, _payload_auto(230)) == []
    fails = gate.compare(base, _payload_auto(250))
    assert any("measured tuned plan" in f for f in fails)


def test_gate_fails_when_auto_row_goes_missing():
    base = _payload_auto(180)
    cur = _payload(100, 300, 200)
    fails = gate.compare(base, cur)
    assert any("auto row present in baseline" in f for f in fails)


def test_gate_auto_row_is_not_share_gated():
    """The auto row is in SKIP_SUFFIXES: its wall-clock participates
    only in the tuned-vs-fixed ratchet, never the share checks."""
    base = _payload_auto(180)
    cur = _payload_auto(180, wave_us=300, mega_us=200)
    # blow up only the auto row within slack of fixed: no share failure
    cur["records"] = [dict(r) for r in cur["records"]]
    for r in cur["records"]:
        if r["name"] == "streaming_alexnet_auto":
            r["us_per_call"] = 239                 # < 200 * 1.2
    assert gate.compare(base, cur) == []


def test_gate_baseline_without_auto_row_accepts_new_row():
    base = _payload(100, 300, 200)
    assert gate.compare(base, _payload_auto(180)) == []


# ---------------------------------------------------------------------------
# Observability rules (ISSUE 9): timing_breakdown presence + the
# disabled-tracer overhead gate
# ---------------------------------------------------------------------------

def _with_breakdown(p, overhead=0.01):
    """Stamp every row with timing_breakdown meta (the instrumented
    bench always emits it) and the megakernel row with the measured
    obs_overhead_frac."""
    for r in p["records"]:
        r.setdefault("meta", {})["timing_breakdown"] = {
            "plan_us": 10.0, "compile_us": 500.0,
            "execute_us": r["us_per_call"]}
        if r["name"] == "streaming_alexnet_megakernel":
            r["meta"]["obs_overhead_frac"] = overhead
    return p


def test_gate_obs_rules_disarmed_without_baseline_meta():
    """Pre-ISSUE-9 baselines carry neither meta key: an instrumented
    current run (or an uninstrumented one) trips nothing."""
    base = _payload(100, 300, 200)
    assert gate.compare(base, _with_breakdown(_payload(100, 300, 200))) \
        == []
    assert gate.compare(base, _payload(100, 300, 200)) == []


def test_gate_obs_rules_pass_on_instrumented_runs():
    base = _with_breakdown(_payload(100, 300, 200))
    assert gate.compare(base, base) == []


def test_gate_fails_on_missing_timing_breakdown():
    """Once the baseline is instrumented, every current row must carry
    the plan/compile/execute split."""
    base = _with_breakdown(_payload(100, 300, 200))
    cur = _with_breakdown(_payload(100, 300, 200))
    del cur["records"][1]["meta"]["timing_breakdown"]   # the wave row
    fails = gate.compare(base, cur)
    assert len(fails) == 1
    assert "streaming_alexnet_wave" in fails[0]
    assert "timing_breakdown" in fails[0]


def test_gate_fails_on_committed_overhead_over_budget():
    """The committed baseline is held strictly to --obs-overhead."""
    base = _with_breakdown(_payload(100, 300, 200), overhead=0.03)
    fails = gate.compare(base, base)
    assert any("committed instrumentation overhead 3.0%" in f
               for f in fails)
    # exactly at budget passes
    base = _with_breakdown(_payload(100, 300, 200), overhead=0.02)
    assert gate.compare(base, base) == []


def test_gate_obs_overhead_current_run_gets_additive_slack():
    base = _with_breakdown(_payload(100, 300, 200), overhead=0.01)
    # 2% budget + 20% threshold slack = 22%: 15% is CI noise, passes
    ok = gate.compare(base, _with_breakdown(_payload(100, 300, 200),
                                            overhead=0.15))
    assert ok == []
    fails = gate.compare(base, _with_breakdown(_payload(100, 300, 200),
                                               overhead=0.25))
    assert any("measured instrumentation overhead 25.0%" in f
               for f in fails)


def test_gate_fails_when_current_run_drops_overhead_meta():
    """Once committed, the overhead measurement must keep appearing or
    the gate cannot be evaluated."""
    base = _with_breakdown(_payload(100, 300, 200))
    cur = _with_breakdown(_payload(100, 300, 200))
    del cur["records"][2]["meta"]["obs_overhead_frac"]
    fails = gate.compare(base, cur)
    assert any("obs_overhead_frac" in f for f in fails)


def test_gate_obs_overhead_knob():
    base = _with_breakdown(_payload(100, 300, 200), overhead=0.04)
    fails = gate.compare(base, base, obs_overhead=0.05)
    assert fails == []
    fails = gate.compare(base, base, obs_overhead=0.01)
    assert any("1.0% budget" in f for f in fails)


def test_gate_negative_overhead_is_fine():
    """min-of-reps noise can land the enabled run faster than the
    disabled one; a negative fraction never fails."""
    base = _with_breakdown(_payload(100, 300, 200), overhead=-0.01)
    assert gate.compare(base, base) == []


# ---------------------------------------------------------------------------
# Grouped-speedup ratchet (ISSUE 10): natural per-group path vs the
# retired block-diagonal expansion
# ---------------------------------------------------------------------------

_DW_ROW = "streaming_grouped_mobilenet_v1_dw_megakernel"
_G2_ROW = "streaming_grouped_alexnet_conv2_g2_megakernel"


def _payload_grouped(dw_speedup=4.0, g2_speedup=1.6, include=True,
                     with_meta=True):
    p = _payload(100, 300, 200)
    if include:
        for name, speed, groups in ((_DW_ROW, dw_speedup, 128),
                                    (_G2_ROW, g2_speedup, 2)):
            meta = {"groups": groups}
            if with_meta:
                meta["speedup_vs_block_diagonal"] = speed
            p["records"].append(
                {"name": name, "us_per_call": 500, "meta": meta})
    return p


def test_gate_grouped_speedup_passes_at_floors():
    base = _payload_grouped(dw_speedup=2.0, g2_speedup=1.3)  # exactly at
    assert gate.compare(base, base) == []


def test_gate_fails_on_weak_committed_grouped_speedup():
    """Acceptance: the committed baseline must meet each row's floor
    strictly — >= 2x depthwise, >= 1.3x on the g=2 conv."""
    base = _payload_grouped(dw_speedup=1.7)
    fails = gate.compare(base, base)
    assert any(_DW_ROW in f and "committed grouped speedup 1.70x" in f
               for f in fails)
    base = _payload_grouped(g2_speedup=1.1)
    fails = gate.compare(base, base)
    assert any(_G2_ROW in f and "required 1.30x" in f for f in fails)


def test_gate_grouped_current_run_gets_threshold_slack():
    base = _payload_grouped(dw_speedup=4.0)
    # 2/(1+0.2) = 1.67 floor: a noisy 1.8x current run passes
    assert gate.compare(base, _payload_grouped(dw_speedup=1.8)) == []
    fails = gate.compare(base, _payload_grouped(dw_speedup=1.5))
    assert any(_DW_ROW in f and "measured grouped speedup 1.50x" in f
               for f in fails)


def test_gate_fails_when_grouped_row_goes_missing():
    """Once committed, the block-diagonal comparison must keep being
    measured — a run without the rows fails instead of disarming."""
    base = _payload_grouped()
    fails = gate.compare(base, _payload_grouped(include=False))
    assert len(fails) == 2
    assert all("grouped-speedup row" in f for f in fails)


def test_gate_fails_when_grouped_meta_dropped():
    base = _payload_grouped()
    fails = gate.compare(base, _payload_grouped(with_meta=False))
    assert len(fails) == 2
    assert all("speedup_vs_block_diagonal meta" in f for f in fails)


def test_gate_baseline_without_grouped_rows_accepts_new_rows():
    """Pre-ISSUE-10 baselines don't trip the ratchet, and new rows in
    the current run are simply not yet gated."""
    base = _payload(100, 300, 200)
    assert gate.compare(base, _payload_grouped()) == []


def test_gate_unknown_grouped_row_is_presence_gated_only():
    """A grouped row outside the floors table (a future case) is
    presence-gated but has no speedup floor."""
    base = _payload(100, 300, 200)
    base["records"].append(
        {"name": "streaming_grouped_future_case_megakernel",
         "us_per_call": 10,
         "meta": {"speedup_vs_block_diagonal": 0.5}})
    assert gate.compare(base, base) == []
    fails = gate.compare(base, _payload(100, 300, 200))
    assert any("streaming_grouped_future_case" in f for f in fails)


def test_gate_grouped_rows_are_not_time_gated():
    """Few-rep single-layer rows: wall-clock alone never fails — the
    ratchet gates the same-run ratio meta."""
    base = _payload_grouped()
    cur = _payload_grouped()
    for r in cur["records"]:
        if r["name"].startswith("streaming_grouped_"):
            r["us_per_call"] *= 10
    assert gate.compare(base, cur) == []


def test_merge_min_takes_min_obs_overhead_across_runs():
    """The overhead fraction is a ratio of two noisy timings: the merge
    takes the per-record minimum across runs even when a different run
    wins the wall-clock."""
    fast_noisy = _with_breakdown(_payload(100, 300, 200), overhead=0.08)
    slow_clean = _with_breakdown(_payload(100, 300, 250), overhead=0.001)
    merged = gate.merge_min([fast_noisy, slow_clean])
    rec = {r["name"]: r for r in merged["records"]}[
        "streaming_alexnet_megakernel"]
    assert rec["us_per_call"] == 200          # fast run wins the clock
    assert rec["meta"]["obs_overhead_frac"] == 0.001
    assert gate.compare(merged, merged) == []
