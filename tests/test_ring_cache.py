"""Ring-buffer local KV cache (§Perf cell-1 optimization): decode through
window-sized caches must equal the full forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.configs.base import applicable_shapes, ALL_SHAPES
from repro.models import transformer as T
from repro.models.module import init_params


@pytest.mark.parametrize("arch", ["gemma3_4b", "recurrentgemma_2b"])
def test_ring_decode_matches_full(arch):
    cfg = dataclasses.replace(reduced_config(arch), compute_dtype="float32")
    assert cfg.window_size > 0
    params = init_params(T.lm_defs(cfg), jax.random.key(0))
    B, S = 2, cfg.window_size + 8   # exceed the window to exercise wrap
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full, _, _ = T.apply_lm(cfg, params, toks)
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32, ring_local=True)
    outs = []
    for t in range(S):
        lg, cache, _ = T.apply_lm(cfg, params, toks[:, t:t + 1],
                                  cache=cache, cache_pos=t)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    assert float(jnp.max(jnp.abs(dec - full))) < 1e-3


def test_ring_cache_is_window_sized():
    cfg = reduced_config("gemma3_4b")
    cache = T.init_cache(cfg, 2, 64, ring_local=True)
    # local offsets: window-sized; global offset: full length
    assert cache["periods"][0]["k"].shape[2] == cfg.window_size
    assert cache["periods"][5]["k"].shape[2] == 64


def test_cell_count_is_33():
    """10 archs x 3 base shapes + 3 long_500k = 33 single-pod cells."""
    from repro.configs import ARCH_IDS, get_config
    n = sum(len(applicable_shapes(get_config(a))) for a in ARCH_IDS)
    assert n == 33
