"""Graceful-degradation runtime (ISSUE 7): every fallback edge
(graphkernel -> megakernel -> wave -> scan, chain-unit demotion, int8's
graphkernel -> megakernel floor) exercised via injected faults with the
degraded output checked against the interpreter / int32 reference, plus
the hardened serving session (input validation, deadlines,
load-shedding, compile retry without cache poisoning)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.decomposition import ConvLayer
from repro.core.graph import INPUT, GraphNode, NetworkGraph, conv_keyed
from repro.core.streaming import (plan_graph, run_graph_reference,
                                  run_graph_streamed)
from repro.distributed.fault import FaultInjector
from repro.launch.session import StreamingSession
from repro.models.cnn import init_graph_weights
from repro.quant.accuracy import quant_graph_reference_acts
from repro.quant.calibrate import calibrate_graph
from repro.runtime import (DeadlineExceeded, FallbackChain,
                           FallbackExhausted, Overloaded,
                           degradation_event_count,
                           reset_degradation_events, resolve_graph,
                           run_graph_degraded)

BUDGET = 64 * 1024


def _conv(name, h, c_in, c_out, inputs, relu=True, pool=1):
    return GraphNode(name, "conv", inputs,
                     layer=ConvLayer(name, h, h, c_in, c_out, 3,
                                     stride=1, pad=1, pool=pool),
                     relu=relu)


def _identity_block():
    nodes = (
        _conv("stem", 8, 3, 8, (INPUT,)),
        _conv("c1", 8, 8, 8, ("stem",)),
        _conv("c2", 8, 8, 8, ("c1",), relu=False),
        GraphNode("add", "add", ("c2", "stem"), relu=True),
    )
    return NetworkGraph("identity_block", (8, 8, 3), nodes, "add")


@pytest.fixture
def block():
    g = _identity_block()
    plans = plan_graph(g, BUDGET)
    ws = init_graph_weights(g, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2,) + g.in_shape)
    ref = run_graph_reference(g, ws, x)[g.output]
    return g, plans, ws, x, ref


# ---------------------------------------------------------------------------
# FallbackChain semantics
# ---------------------------------------------------------------------------

def test_chain_order_enforced():
    FallbackChain(("graphkernel", "wave"))          # subset OK
    with pytest.raises(ValueError, match="order"):
        FallbackChain(("wave", "megakernel"))
    with pytest.raises(ValueError, match="unknown fallback mode"):
        FallbackChain(("interpret",))
    assert FallbackChain().next_mode("scan") is None
    assert FallbackChain().from_mode("wave") == ("wave", "scan")


# ---------------------------------------------------------------------------
# Every fallback edge, with output parity vs the interpreter reference
# ---------------------------------------------------------------------------

def test_clean_run_full_fidelity_zero_events(block):
    g, plans, ws, x, ref = block
    reset_degradation_events()
    y, res = run_graph_degraded(g, plans, x, ws)
    assert set(res.node_modes.values()) == {"graphkernel"}
    assert res.events == [] and degradation_event_count() == 0
    assert jnp.allclose(y, ref, atol=1e-4)


def test_graphkernel_to_megakernel_only_faulted_node_degrades(block):
    g, plans, ws, x, ref = block
    with FaultInjector() as fi:
        fi.arm("plan", node="c1", mode="graphkernel")
        y, res = run_graph_degraded(g, plans, x, ws)
    assert res.node_modes["c1"] == "megakernel"
    # the rest of the graph keeps kernel-mode plans (chains can't span
    # the degraded node, so survivors settle as megakernels — but
    # nothing falls to wave/scan)
    assert all(m in ("graphkernel", "megakernel")
               for m in res.node_modes.values())
    # exactly ONE structured event, naming node / edge / stage / cause
    (ev,) = res.events
    assert ev.node == "c1" and ev.stage == "plan" and ev.retry == 1
    assert (ev.from_mode, ev.to_mode) == ("graphkernel", "megakernel")
    assert "PlanError" in ev.cause and "injected" in ev.cause
    assert jnp.allclose(y, ref, atol=1e-4)


def test_megakernel_to_wave_edge(block):
    g, plans, ws, x, ref = block
    with FaultInjector() as fi:
        fi.arm("plan", node="c1", mode="graphkernel")
        fi.arm("launch", node="c1", mode="megakernel")
        y, res = run_graph_degraded(g, plans, x, ws)
    assert res.node_modes["c1"] == "wave"
    assert [(e.from_mode, e.to_mode, e.retry) for e in res.events] == \
        [("graphkernel", "megakernel", 1), ("megakernel", "wave", 2)]
    assert res.events[1].stage == "launch"
    assert jnp.allclose(y, ref, atol=1e-4)


def test_wave_to_scan_edge(block):
    g, plans, ws, x, ref = block
    with FaultInjector() as fi:
        fi.arm("plan", node="c2", mode="graphkernel")
        fi.arm("plan", node="c2", mode="megakernel")
        fi.arm("lower", node="c2", mode="wave")
        y, res = run_graph_degraded(g, plans, x, ws)
    assert res.node_modes["c2"] == "scan"
    assert [e.to_mode for e in res.events] == \
        ["megakernel", "wave", "scan"]
    assert jnp.allclose(y, ref, atol=1e-4)


def test_chain_unit_fault_demotes_all_members_with_one_event(block):
    g, plans, ws, x, ref = block
    with FaultInjector() as fi:
        # launch@graphkernel on the chain HEAD = the fused chain's own
        # launch failing — the chain degrades as a unit
        fi.arm("launch", node="stem", mode="graphkernel")
        y, res = run_graph_degraded(g, plans, x, ws)
    assert set(res.node_modes.values()) == {"megakernel"}
    assert res.chains == ()
    (ev,) = res.events
    assert ev.stage == "chain" and ev.node == "stem"
    assert "stem+c1+c2" in ev.cause       # names the demoted members
    assert jnp.allclose(y, ref, atol=1e-4)


def test_vmem_budget_fault_forces_budget_exceeded_edge(block):
    g, plans, ws, x, ref = block
    with FaultInjector() as fi:
        fi.arm_vmem(128, node="c2")       # nothing lowers into 128 bytes
        y, res = run_graph_degraded(g, plans, x, ws)
    assert res.node_modes["c2"] == "wave"
    assert [e.stage for e in res.events] == ["budget", "budget"]
    assert jnp.allclose(y, ref, atol=1e-4)


def test_exhaustion_at_terminal_mode_raises_chained(block):
    g, plans, ws, x, _ = block
    with FaultInjector() as fi:
        for mode in ("graphkernel", "megakernel", "wave", "scan"):
            fi.arm("plan", node="c1", mode=mode)
        with pytest.raises(FallbackExhausted, match="terminal mode"):
            run_graph_degraded(g, plans, x, ws)


def test_mode_argument_starts_partway_down_the_chain(block):
    g, plans, ws, x, ref = block
    y, res = run_graph_degraded(g, plans, x, ws, mode="wave")
    assert set(res.node_modes.values()) == {"wave"}
    assert res.events == []
    assert jnp.allclose(y, ref, atol=1e-4)


def test_degraded_output_matches_undegraded_wave_exactly(block):
    """A node degraded to wave runs the SAME executor the all-wave
    session runs — bitwise, not approximately."""
    g, plans, ws, x, _ = block
    y_wave = run_graph_streamed(g, plans, x, ws, mode="wave")
    with FaultInjector() as fi:
        fi.arm("plan", node="c1", mode="graphkernel")
        fi.arm("plan", node="c1", mode="megakernel")
        y, res = run_graph_degraded(g, plans, x, ws)
    assert res.node_modes["c1"] == "wave"
    assert jnp.allclose(y, y_wave, atol=1e-5)


# ---------------------------------------------------------------------------
# int8: graphkernel -> megakernel only, bit-exact vs the int32 reference
# ---------------------------------------------------------------------------

def test_int8_edge_bit_exact_vs_int32_reference(block):
    g, plans, ws, x, _ = block
    qg = calibrate_graph(g, ws, x)
    ref_q = quant_graph_reference_acts(qg, x)[g.output]
    with FaultInjector() as fi:
        fi.arm("lower", node="c1", mode="graphkernel")
        y, res = run_graph_degraded(g, plans, x, ws, precision="int8",
                                    qgraph=qg, dequantize=False)
    assert res.node_modes["c1"] == "megakernel"
    (ev,) = res.events
    assert ev.stage == "lower" and "LoweringError" in ev.cause
    assert jnp.array_equal(y, ref_q)      # bit-exact, no tolerance


def test_int8_has_no_wave_floor(block):
    g, plans, ws, x, _ = block
    qg = calibrate_graph(g, ws, x)
    with FaultInjector() as fi:
        fi.arm("plan", node="c1", mode="graphkernel")
        fi.arm("plan", node="c1", mode="megakernel")
        with pytest.raises(FallbackExhausted):
            run_graph_degraded(g, plans, x, ws, precision="int8",
                               qgraph=qg)


# ---------------------------------------------------------------------------
# Executable-cache hygiene: degraded signatures never collide with clean
# ---------------------------------------------------------------------------

def test_resolved_signature_distinguishes_degradation(block):
    g, plans, ws, x, _ = block
    from repro.core.streaming import compile_graph
    programs = compile_graph(g, plan_graph(g, BUDGET))
    clean = resolve_graph(g, programs)
    with FaultInjector() as fi:
        fi.arm("plan", node="c1", mode="graphkernel")
        degraded = resolve_graph(g, programs)
    assert clean.signature() != degraded.signature()
    # the signature also keys armed poisons (it reads the LIVE arms, so
    # the key is computed at call time): a poisoned trace must not
    # serve clean traffic
    clean_sig = clean.signature()
    with FaultInjector() as fi:
        fi.arm_nan("c1")
        poisoned = resolve_graph(g, programs)
        assert poisoned.signature() != clean_sig


def test_degraded_then_clean_run_does_not_reuse_degraded_executable(block):
    g, plans, ws, x, ref = block
    with FaultInjector() as fi:
        fi.arm("plan", node="c1", mode="graphkernel")
        y_deg, res_deg = run_graph_degraded(g, plans, x, ws)
    y_clean, res_clean = run_graph_degraded(g, plans, x, ws)
    assert res_deg.node_modes != res_clean.node_modes
    assert set(res_clean.node_modes.values()) == {"graphkernel"}
    assert jnp.allclose(y_clean, ref, atol=1e-4)


# ---------------------------------------------------------------------------
# Hardened serving session
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("network", ["alexnet", "vgg16", "resnet18"])
def test_session_input_validation_names_expected_spec(network):
    from repro.core.model_zoo import network_graph
    g = network_graph(network)
    ws = init_graph_weights(g, jax.random.key(0))
    sess = StreamingSession.for_graph(g, ws, max_batch=2)
    H, W, C = g.in_shape
    with pytest.raises(ValueError) as ei:
        sess.run_batch(jnp.zeros((1, H + 1, W, C)))
    assert f"(B, {H}, {W}, {C})" in str(ei.value)
    with pytest.raises(ValueError, match="dtype int32"):
        sess.run_batch(jnp.zeros((1, H, W, C), jnp.int32))
    with pytest.raises(ValueError, match="NaN/Inf"):
        sess.run_batch(jnp.full((1, H, W, C), jnp.inf))
    with pytest.raises(ValueError, match=f"\\({H}, {W}, {C}\\)"):
        sess.submit(jnp.zeros((H, W, C + 1)))


def _mini_session(**kw):
    nodes = (_conv("stem", 8, 3, 8, (INPUT,)),
             _conv("c1", 8, 8, 8, ("stem",)))
    g = NetworkGraph("mini", (8, 8, 3), nodes, "c1")
    ws = init_graph_weights(g, jax.random.key(0))
    return StreamingSession.for_graph(g, ws, sram_budget=BUDGET, **kw), g, ws


def test_session_load_shedding_bounded_queue():
    sess, g, _ = _mini_session(max_batch=8, max_pending=2)
    img = jnp.zeros(g.in_shape)
    t1, t2 = sess.submit(img), sess.submit(img)
    with pytest.raises(Overloaded, match="queue full"):
        sess.submit(img)
    assert sess.shed == 1
    sess.flush()                           # draining reopens the queue
    t3 = sess.submit(img)
    assert sess.result(t1).shape == sess.result(t3).shape


def test_session_deadline_expiry_sheds_stale_requests():
    now = [0.0]
    sess, g, _ = _mini_session(max_batch=8, clock=lambda: now[0])
    img = jnp.zeros(g.in_shape)
    stale = sess.submit(img, deadline=1.0)
    live = sess.submit(img)
    now[0] = 5.0
    sess.flush()
    with pytest.raises(DeadlineExceeded, match="deadline passed"):
        sess.result(stale)
    assert sess.deadline_expired == 1
    assert sess.result(live).shape == (8, 8, 8)   # live one still served


def test_session_compile_retry_evicts_failed_executable():
    sleeps = []
    sess, g, _ = _mini_session(max_batch=2, compile_retries=2,
                               backoff_base=0.05,
                               sleep_fn=sleeps.append)
    x = jax.random.normal(jax.random.key(1), (2,) + g.in_shape)
    good = sess._forward
    fails = [1]

    def flaky(xx, w, o):
        if fails[0] > 0:
            fails[0] -= 1
            raise RuntimeError("transient compile blowup")
        return good(xx, w, o)

    sess._forward = flaky
    y = sess.run_batch(x)
    assert y.shape == (2, 8, 8, 8)
    assert sleeps == [0.05]                # deterministic backoff
    assert sess.compile_retries_used == 1
    # the failed executable was evicted BEFORE the retry — the cache
    # holds exactly the one good executable, never the poisoned one
    assert len(sess._executables) == 1
    assert sess.run_batch(x).shape == (2, 8, 8, 8)


def test_session_compile_failure_exhausts_retries_and_raises():
    sess, g, _ = _mini_session(max_batch=2, compile_retries=1,
                               sleep_fn=lambda _: None)
    x = jnp.zeros((2,) + g.in_shape)

    def always_bad(xx, w, o):
        raise RuntimeError("permanent lowering bug")

    sess._forward = always_bad
    with pytest.raises(RuntimeError, match="permanent lowering bug"):
        sess.run_batch(x)
    assert sess._executables == {}         # nothing poisoned the cache


def test_session_fallback_reports_modes_and_health():
    with FaultInjector() as fi:
        fi.arm("plan", node="c1", mode="graphkernel")
        sess, g, _ = _mini_session(max_batch=2, mode="graphkernel",
                                   fallback=True)
    assert sess.resolved.node_modes["c1"] == "megakernel"
    x = jax.random.normal(jax.random.key(1), (2,) + g.in_shape)
    ws = init_graph_weights(g, jax.random.key(0))
    ref = run_graph_reference(g, ws, x)[g.output]
    assert jnp.allclose(sess.run_batch(x), ref, atol=1e-4)
    h = sess.health()
    assert h["node_modes"]["c1"] == "megakernel"
    assert len(h["degradation_events"]) == 1
    assert h["degradation_events"][0]["node"] == "c1"
    assert "fallback: " in sess.describe()


def test_session_executable_key_carries_mode_precision_signature():
    sess, g, _ = _mini_session(max_batch=2, mode="graphkernel",
                               fallback=True)
    key = sess._exec_key((2, 8, 8, 3), "float32")
    assert "graphkernel" in key and "fp32" in key
    assert sess.resolved.signature() in key
