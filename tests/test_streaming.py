"""Streaming tiled executor == direct convolution (the paper's §3+§5
correctness claim) under randomized plans — and through the Pallas kernel."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp

from repro.core.decomposition import (ALEXNET_LAYERS, ConvLayer, evaluate,
                                      plan_decomposition)
from repro.core.streaming import (conv2d_direct, maxpool_direct,
                                  run_layer_streamed, run_network_streamed)
from repro.kernels.conv_stream import conv2d_stream


@hypothesis.given(
    st.integers(6, 24), st.integers(6, 24),
    st.integers(1, 8), st.integers(1, 12),
    st.sampled_from([1, 3, 5]), st.sampled_from([1, 2]),
    st.integers(0, 2),
    st.integers(1, 3), st.integers(1, 3), st.sampled_from([1, 2, 3]),
)
@hypothesis.settings(max_examples=25, deadline=None)
def test_streamed_equals_direct_random(h, w, cin, cout, k, stride, pad,
                                       th, tw, fs):
    layer = ConvLayer("t", h, w, cin, cout, k, stride=stride, pad=pad)
    if layer.out_h <= 0 or layer.out_w <= 0 or fs > cout:
        return
    plan = evaluate(layer, th, tw, fs, 1)
    if plan is None:
        return
    x = jax.random.normal(jax.random.key(0), (1, h, w, cin))
    wts = jax.random.normal(jax.random.key(1), (k, k, cin, cout)) * 0.2
    direct = conv2d_direct(x, wts, stride, pad)
    streamed = run_layer_streamed(layer, plan, x, wts)
    assert jnp.max(jnp.abs(direct - streamed)) < 1e-4


def test_alexnet_conv1_streamed_under_paper_budget():
    l1 = ALEXNET_LAYERS[0]
    plan = plan_decomposition(l1, 128 * 1024)
    x = jax.random.normal(jax.random.key(0), (1, 227, 227, 3))
    w = jax.random.normal(jax.random.key(1), (11, 11, 3, 96)) * 0.05
    assert plan.sram_needed <= 128 * 1024
    direct = conv2d_direct(x, w, 4, 0)
    streamed = run_layer_streamed(l1, plan, x, w)
    assert jnp.max(jnp.abs(direct - streamed)) < 1e-3


def test_streamed_network_stack():
    layers = (ConvLayer("a", 16, 16, 3, 8, 3, pad=1, pool=2),
              ConvLayer("b", 8, 8, 8, 16, 3, pad=1))
    plans = [plan_decomposition(l, 64 * 1024) for l in layers]
    weights = []
    for i, l in enumerate(layers):
        w = jax.random.normal(jax.random.key(i), (l.kernel, l.kernel,
                                                  l.in_c, l.out_c)) * 0.2
        b = jnp.zeros((l.out_c,))
        weights.append((w, b))
    x = jax.random.normal(jax.random.key(9), (2, 16, 16, 3))
    got = run_network_streamed(layers, plans, x, weights)
    # direct reference
    y = x
    for l, (w, b) in zip(layers, weights):
        y = jnp.maximum(conv2d_direct(y, w, l.stride, l.pad) + b, 0)
        if l.pool > 1:
            y = maxpool_direct(y, l.pool, l.pool_stride or l.pool)
    assert jnp.max(jnp.abs(got - y)) < 1e-4


def test_streamed_with_pallas_kernel_backend():
    """The executor's pluggable conv backend: Pallas streaming kernel."""
    layer = ConvLayer("pk", 16, 16, 4, 8, 3, stride=1, pad=0)
    plan = evaluate(layer, 2, 1, 2, 1)
    x = jax.random.normal(jax.random.key(0), (1, 16, 16, 4))
    w = jax.random.normal(jax.random.key(1), (3, 3, 4, 8)) * 0.2

    def pallas_conv(xt, wt):
        return conv2d_stream(xt, wt, stride=layer.stride, row_block=4)

    got = run_layer_streamed(layer, plan, x, w, conv_fn=pallas_conv)
    ref = conv2d_direct(x, w, 1, 0)
    assert jnp.max(jnp.abs(got - ref)) < 1e-4
