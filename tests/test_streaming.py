"""Streaming tiled executor == direct convolution (the paper's §3+§5
correctness claim) — interpreted and compiled (scan) executors, the
Pallas kernel backend, and the StreamingSession serving layer."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.decomposition import (ALEXNET_LAYERS, ALEXNET_STACK,
                                      ConvLayer, evaluate,
                                      plan_decomposition)
from repro.core.streaming import (conv2d_direct, maxpool_direct,
                                  run_layer_interpreted, run_layer_streamed,
                                  run_network_streamed)
from repro.kernels.conv_stream import conv2d_stream
from repro.launch.session import StreamingSession

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # dev-only dependency (requirements.txt)
    hypothesis = None


def _layer_weights(layer, key=1, scale=0.2):
    l = layer
    w = jax.random.normal(jax.random.key(key),
                          (l.kernel, l.kernel, l.in_c // l.groups,
                           l.out_c)) * scale
    return w


def test_alexnet_conv1_streamed_under_paper_budget():
    l1 = ALEXNET_LAYERS[0]
    plan = plan_decomposition(l1, 128 * 1024)
    x = jax.random.normal(jax.random.key(0), (1, 227, 227, 3))
    w = jax.random.normal(jax.random.key(1), (11, 11, 3, 96)) * 0.05
    assert plan.sram_needed <= 128 * 1024
    direct = conv2d_direct(x, w, 4, 0)
    streamed = run_layer_streamed(l1, plan, x, w)
    assert jnp.max(jnp.abs(direct - streamed)) < 1e-3


def test_streamed_network_stack():
    layers = (ConvLayer("a", 16, 16, 3, 8, 3, pad=1, pool=2),
              ConvLayer("b", 8, 8, 8, 16, 3, pad=1))
    plans = [plan_decomposition(l, 64 * 1024) for l in layers]
    weights = []
    for i, l in enumerate(layers):
        w = jax.random.normal(jax.random.key(i), (l.kernel, l.kernel,
                                                  l.in_c, l.out_c)) * 0.2
        b = jnp.zeros((l.out_c,))
        weights.append((w, b))
    x = jax.random.normal(jax.random.key(9), (2, 16, 16, 3))
    got = run_network_streamed(layers, plans, x, weights)
    got_interp = run_network_streamed(layers, plans, x, weights,
                                      mode="interpret")
    # direct reference
    y = x
    for l, (w, b) in zip(layers, weights):
        y = jnp.maximum(conv2d_direct(y, w, l.stride, l.pad) + b, 0)
        if l.pool > 1:
            y = maxpool_direct(y, l.pool, l.pool_stride or l.pool)
    assert jnp.max(jnp.abs(got - y)) < 1e-4
    assert jnp.array_equal(got, got_interp)


def test_streamed_with_pallas_kernel_backend():
    """The executor's pluggable conv backend: Pallas streaming kernel."""
    layer = ConvLayer("pk", 16, 16, 4, 8, 3, stride=1, pad=0)
    plan = evaluate(layer, 2, 1, 2, 1)
    x = jax.random.normal(jax.random.key(0), (1, 16, 16, 4))
    w = jax.random.normal(jax.random.key(1), (3, 3, 4, 8)) * 0.2

    def pallas_conv(xt, wt):
        return conv2d_stream(xt, wt, stride=layer.stride, row_block=4)

    got = run_layer_streamed(layer, plan, x, w, conv_fn=pallas_conv,
                             mode="interpret")
    ref = conv2d_direct(x, w, 1, 0)
    assert jnp.max(jnp.abs(got - ref)) < 1e-4
    # and as a first-class backend of the compiled scan executor
    got_jit = run_layer_streamed(layer, plan, x, w, conv_backend="pallas")
    assert jnp.max(jnp.abs(got_jit - ref)) < 1e-4


# ---------------------------------------------------------------------------
# Compiled (scan) executor: bit-identical replay of the schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layer", ALEXNET_LAYERS, ids=lambda l: l.name)
def test_scan_executor_bit_identical_alexnet(layer):
    """Across all AlexNet layers (stride 4, pad 2, grouped convs), under
    the paper's own 128 KB plans: the compiled executor reproduces the
    interpreted tile walk bit for bit, and the direct conv bit for bit
    whenever there is no partial-sum split to reassociate."""
    plan = plan_decomposition(layer, 128 * 1024)
    l = layer
    x = jax.random.normal(jax.random.key(0), (2, l.in_h, l.in_w, l.in_c))
    w = _layer_weights(l, scale=0.05)
    b = jax.random.normal(jax.random.key(7), (l.out_c,)) * 0.1
    jit_out = run_layer_streamed(l, plan, x, w, b)
    interp = run_layer_interpreted(l, plan, x, w, b)
    assert jnp.array_equal(jit_out, interp), "scan executor != tile loop"
    direct = conv2d_direct(x, w, l.stride, l.pad, groups=l.groups) + b
    if plan.in_splits == 1:
        assert jnp.array_equal(jit_out, direct), "scan executor != direct"
    else:  # partial sums reassociate the channel reduction: ULP-level
        assert jnp.max(jnp.abs(jit_out - direct)) < 1e-4


@pytest.mark.parametrize("th,tw,fs,cs", [(1, 1, 1, 1), (3, 2, 2, 1),
                                         (2, 2, 1, 2), (2, 3, 4, 4)])
def test_scan_executor_matches_loop_random_plans(th, tw, fs, cs):
    layer = ConvLayer("t", 21, 17, 8, 12, 3, stride=2, pad=1)
    plan = evaluate(layer, th, tw, fs, cs)
    assert plan is not None
    x = jax.random.normal(jax.random.key(3), (1, 21, 17, 8))
    w = _layer_weights(layer)
    got = run_layer_streamed(layer, plan, x, w)
    ref = run_layer_interpreted(layer, plan, x, w)
    assert jnp.max(jnp.abs(got - ref)) < 1e-5
    assert jnp.max(jnp.abs(got - conv2d_direct(x, w, 2, 1))) < 1e-4


def test_scan_executor_unreachable_trailing_rows():
    """(in - K) % stride != 0 leaves trailing rows the conv window never
    reads; the tile grid is then *smaller* than the padded input and the
    executor must trim, not negative-pad (regression)."""
    layer = ConvLayer("t", 8, 8, 4, 8, 3, stride=2)
    plan = evaluate(layer, 1, 1, 1, 1)
    x = jax.random.normal(jax.random.key(0), (1, 8, 8, 4))
    w = _layer_weights(layer)
    got = run_layer_streamed(layer, plan, x, w)
    assert jnp.array_equal(got, run_layer_interpreted(layer, plan, x, w))
    assert jnp.max(jnp.abs(got - conv2d_direct(x, w, 2, 0))) < 1e-5


def test_scan_executor_rejects_mismatched_input():
    l1 = ALEXNET_LAYERS[0]
    plan = plan_decomposition(l1, 128 * 1024)
    x = jax.random.normal(jax.random.key(0), (1, 55, 55, 3))  # wrong dims
    with pytest.raises(ValueError, match="declared"):
        run_layer_streamed(l1, plan, x, _layer_weights(l1))


# ---------------------------------------------------------------------------
# StreamingSession: compiled multi-image serving
# ---------------------------------------------------------------------------

def _small_net():
    layers = (ConvLayer("a", 16, 16, 3, 8, 3, pad=1, pool=2),
              ConvLayer("b", 8, 8, 8, 16, 3, pad=1))
    weights = []
    for i, l in enumerate(layers):
        w = jax.random.normal(jax.random.key(i), (l.kernel, l.kernel,
                                                  l.in_c, l.out_c)) * 0.2
        weights.append((w, jnp.zeros((l.out_c,))))
    return layers, weights


def _direct_net(layers, weights, x):
    y = x
    for l, (w, b) in zip(layers, weights):
        y = jnp.maximum(conv2d_direct(y, w, l.stride, l.pad,
                                      groups=l.groups) + b, 0)
        if l.pool > 1:
            y = maxpool_direct(y, l.pool, l.pool_stride or l.pool)
    return y


def test_session_reuses_compiled_executable():
    layers, weights = _small_net()
    sess = StreamingSession.for_network(layers, weights,
                                        sram_budget=64 * 1024, max_batch=4)
    x = jax.random.normal(jax.random.key(5), (4, 16, 16, 3))
    y1 = sess.run_batch(x)
    y2 = sess.run_batch(x + 1.0)
    y3 = sess.run_batch(x * 2.0)
    assert sess.compile_count == 1, "repeat batches must not retrace"
    assert sess.calls == 3
    assert jnp.max(jnp.abs(y1 - _direct_net(layers, weights, x))) < 1e-4
    assert not jnp.array_equal(y2, y3)
    # a new batch shape compiles exactly once more
    sess.run_batch(jax.random.normal(jax.random.key(6), (2, 16, 16, 3)))
    assert sess.compile_count == 2


def test_session_microbatch_queue():
    """Single-image submits coalesce into shared compiled batches."""
    layers, weights = _small_net()
    sess = StreamingSession.for_network(layers, weights,
                                        sram_budget=64 * 1024, max_batch=4)
    imgs = jax.random.normal(jax.random.key(8), (6, 16, 16, 3))
    tickets = [sess.submit(imgs[i]) for i in range(6)]
    assert sess.calls == 1          # 4 submits auto-flushed one batch
    assert sess.pending == 2
    outs = [sess.result(t) for t in tickets]   # flushes the remainder
    assert sess.pending == 0
    assert sess.calls == 2
    assert sess.compile_count == 1, "padded partial flush must reuse exe"
    ref = _direct_net(layers, weights, imgs)
    for i, o in enumerate(outs):
        assert jnp.max(jnp.abs(o - ref[i])) < 1e-4
    with pytest.raises(KeyError, match="already fetched"):
        sess.result(tickets[0])           # double-fetch is an error
    t = sess.submit(imgs[0])
    sess.discard(t)                        # abandoned ticket drops cleanly
    assert sess.pending == 0


def test_session_alexnet_stack_smoke():
    """The full pooled AlexNet stack serves a batch through one compile."""
    weights = [(_layer_weights(l, key=i, scale=0.05),
                jnp.zeros((l.out_c,)))
               for i, l in enumerate(ALEXNET_STACK)]
    sess = StreamingSession.for_network(ALEXNET_STACK, weights,
                                        max_batch=2)
    x = jax.random.normal(jax.random.key(0), (2, 227, 227, 3))
    y = sess.run_batch(x)
    assert y.shape == (2, 6, 6, 256)
    assert sess.compile_count == 1
    ref = _direct_net(ALEXNET_STACK, weights, x)
    assert jnp.max(jnp.abs(y - ref)) < 1e-3


# ---------------------------------------------------------------------------
# Property-based cases (skipped cleanly without hypothesis)
# ---------------------------------------------------------------------------

if hypothesis is not None:
    @hypothesis.given(
        st.integers(6, 24), st.integers(6, 24),
        st.integers(1, 8), st.integers(1, 12),
        st.sampled_from([1, 3, 5]), st.sampled_from([1, 2]),
        st.integers(0, 2),
        st.integers(1, 3), st.integers(1, 3), st.sampled_from([1, 2, 3]),
    )
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_streamed_equals_direct_random(h, w, cin, cout, k, stride, pad,
                                           th, tw, fs):
        layer = ConvLayer("t", h, w, cin, cout, k, stride=stride, pad=pad)
        if layer.out_h <= 0 or layer.out_w <= 0 or fs > cout:
            return
        plan = evaluate(layer, th, tw, fs, 1)
        if plan is None:
            return
        x = jax.random.normal(jax.random.key(0), (1, h, w, cin))
        wts = jax.random.normal(jax.random.key(1), (k, k, cin, cout)) * 0.2
        direct = conv2d_direct(x, wts, stride, pad)
        streamed = run_layer_streamed(layer, plan, x, wts)
        interp = run_layer_interpreted(layer, plan, x, wts)
        assert jnp.max(jnp.abs(direct - streamed)) < 1e-4
        assert jnp.max(jnp.abs(interp - streamed)) < 1e-5
else:
    def test_property_cases_need_hypothesis():
        pytest.importorskip("hypothesis")  # skips, visibly
