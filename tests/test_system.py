"""End-to-end behaviour tests for the paper's system: a CNN trained through
the streaming substrate learns; quantized streaming inference matches float
within fixed-point error; tiled large-image inference works (the FPGA
face-detection demo analogue)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core.decomposition import ConvLayer, plan_decomposition
from repro.core.quantization import (calibrate_frac_bits, dequantize,
                                     quantize)
from repro.core.streaming import (conv2d_direct, maxpool_direct,
                                  run_layer_streamed)
from repro.data.pipeline import cnn_batch
from repro.models.cnn import apply_cnn, cnn_defs, tiny_cnn_config
from repro.models.module import init_params
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.train.losses import cnn_loss


def test_cnn_trains_on_streaming_substrate():
    cfg = tiny_cnn_config(num_classes=4)
    params = init_params(cnn_defs(cfg), jax.random.key(0))
    opt = adamw_init(params)
    tcfg = TrainConfig(learning_rate=3e-3)

    @jax.jit
    def step(params, opt, step_i, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: cnn_loss(cfg, p, batch), has_aux=True)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(params, grads, opt, step_i, tcfg)
        return params, opt, metrics

    losses = []
    for i in range(25):
        batch = cnn_batch(0, i, 16, 32, 3, 4)
        params, opt, m = step(params, opt, jnp.asarray(i + 1), batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_quantized_streaming_inference_matches_float():
    """16-bit fixed-point conv (the paper's datapath) through the streaming
    executor tracks the float result within accumulated LSB error."""
    layer = ConvLayer("q", 16, 16, 8, 16, 3, pad=0)
    plan = plan_decomposition(layer, 64 * 1024)
    x = jax.random.normal(jax.random.key(0), (1, 16, 16, 8))
    w = jax.random.normal(jax.random.key(1), (3, 3, 8, 16)) * 0.2
    qx = calibrate_frac_bits(x, 16)
    qw = calibrate_frac_bits(w, 16)
    xq = dequantize(quantize(x, qx), qx)
    wq = dequantize(quantize(w, qw), qw)
    got = run_layer_streamed(layer, plan, xq, wq)
    ref = conv2d_direct(x, w, 1, 0)
    fan_in = 3 * 3 * 8
    tol = fan_in * (qx.lsb * float(jnp.max(jnp.abs(w)))
                    + qw.lsb * float(jnp.max(jnp.abs(x))))
    assert float(jnp.max(jnp.abs(got - ref))) < tol


def test_large_image_tiled_inference():
    """Arbitrary-size input through a fixed small buffer (paper's claim):
    a 128x96 image convolved under a 24 KB budget, tile by tile."""
    layer = ConvLayer("big", 96, 128, 3, 8, 3, pad=1, bytes_per_elem=2)
    plan = plan_decomposition(layer, 24 * 1024)
    assert plan.tiles_h * plan.tiles_w > 1  # decomposition actually engaged
    x = jax.random.normal(jax.random.key(0), (1, 96, 128, 3))
    w = jax.random.normal(jax.random.key(1), (3, 3, 3, 8)) * 0.2
    got = run_layer_streamed(layer, plan, x, w)
    ref = conv2d_direct(x, w, 1, 1)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4


def test_data_pipeline_deterministic_in_step():
    a = cnn_batch(7, 3, 4, 16, 3, 10)
    b = cnn_batch(7, 3, 4, 16, 3, 10)
    np.testing.assert_array_equal(np.asarray(a["images"]),
                                  np.asarray(b["images"]))
    c = cnn_batch(7, 4, 4, 16, 3, 10)
    assert not np.array_equal(np.asarray(a["images"]),
                              np.asarray(c["images"]))
