"""Training-loop integration: convergence, determinism across restart,
grad accumulation equivalence, watchdog, compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import lm_batch
from repro.distributed.fault import StepWatchdog, run_with_restarts
from repro.models import transformer as T
from repro.models.module import init_params
from repro.train.loop import train_lm
from repro.train.steps import init_train_state, make_train_step


from conftest import optimization_barrier_differentiable

# pre-existing seed failure, triaged (ISSUE 5 satellite): the pinned
# jax has no differentiation rule for optimization_barrier
# (src/repro/train/losses.py uses it to pin the bf16 cast), so every
# grad-taking training-loop test dies at the first step. Applied per
# grad-taking test (NOT module-wide): the watchdog/restart-policy
# tests take no grads and keep failing loudly on real regressions.
xfail_no_optbar_grad = pytest.mark.xfail(
    condition=not optimization_barrier_differentiable(),
    reason="installed jax cannot differentiate optimization_barrier "
           "(train/losses.py pins the compute-dtype cast with it); "
           "needs a newer jax pin",
    strict=False)


def _cfg():
    return dataclasses.replace(reduced_config("qwen3_1p7b"),
                               compute_dtype="float32")


@xfail_no_optbar_grad
def test_loss_decreases_on_learnable_data():
    cfg = _cfg()
    _, hist = train_lm(cfg, TrainConfig(learning_rate=3e-3), num_steps=30,
                       batch=8, seq=32)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.9


@xfail_no_optbar_grad
def test_crash_restart_resumes_from_checkpoint(tmp_path):
    cfg = _cfg()
    tcfg = TrainConfig(learning_rate=1e-3, checkpoint_every=5)
    calls = {"n": 0}

    def make_runner():
        def run():
            first = calls["n"] == 0
            calls["n"] += 1
            _, h = train_lm(cfg, tcfg, num_steps=12, batch=4, seq=16,
                            ckpt_dir=str(tmp_path),
                            fail_at_step=7 if first else None)
            return len(h)
        return run

    steps_after_restart = run_with_restarts(make_runner, max_restarts=2)
    # failed at step 7 after checkpointing step 5 -> resumed at 5, ran 7 more
    assert steps_after_restart == 12 - 5


@xfail_no_optbar_grad
def test_restart_matches_uninterrupted_run(tmp_path):
    """Determinism: crash+restore reproduces the uninterrupted loss curve."""
    cfg = _cfg()
    tcfg = TrainConfig(learning_rate=1e-3, checkpoint_every=4)
    _, clean = train_lm(cfg, tcfg, num_steps=10, batch=4, seq=16)
    try:
        train_lm(cfg, tcfg, num_steps=10, batch=4, seq=16,
                 ckpt_dir=str(tmp_path), fail_at_step=6)
    except RuntimeError:
        pass
    _, resumed = train_lm(cfg, tcfg, num_steps=10, batch=4, seq=16,
                          ckpt_dir=str(tmp_path))
    # resumed history covers steps 4..9; compare the overlap
    np.testing.assert_allclose(
        [h["loss"] for h in resumed],
        [h["loss"] for h in clean[4:]], rtol=1e-4)


@xfail_no_optbar_grad
def test_grad_accumulation_matches_single_batch():
    cfg = _cfg()
    params = init_params(T.lm_defs(cfg), jax.random.key(0))
    batch = lm_batch(0, 0, 8, 16, cfg.vocab_size)
    s1 = init_train_state(cfg, params)
    s2 = jax.tree.map(jnp.copy, s1)
    one = make_train_step(cfg, TrainConfig(learning_rate=1e-3, accum_steps=1))
    acc = make_train_step(cfg, TrainConfig(learning_rate=1e-3, accum_steps=4))
    n1, m1 = jax.jit(one)(s1, batch)
    n2, m2 = jax.jit(acc)(s2, batch)
    # same global batch, same mean gradient -> same update (fp32 tolerance)
    diffs = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        n1["params"], n2["params"]))
    assert max(diffs) < 1e-4   # fp32 summation-order tolerance
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4


@xfail_no_optbar_grad
def test_int8_grad_compression_still_converges():
    cfg = _cfg()
    tcfg = TrainConfig(learning_rate=3e-3, accum_steps=2,
                       grad_compression="int8")
    _, hist = train_lm(cfg, tcfg, num_steps=20, batch=8, seq=32)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(ratio=3.0, warmup=3)
    for _ in range(10):
        wd.observe(0.1)
    assert wd.observe(1.0) is True
    assert wd.stragglers == 1
    assert wd.observe(0.1) is False


def test_run_with_restarts_gives_up_after_max():
    def make_runner():
        def run():
            raise RuntimeError("always fails")
        return run

    with pytest.raises(RuntimeError):
        run_with_restarts(make_runner, max_restarts=2)
