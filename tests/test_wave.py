"""Wave-parallel schedule replay (ISSUE 2): bit-exactness against the
interpreted tile walk, wave-partition safety properties, the fused
conv+pool network path, and executor-cache hygiene."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.decomposition import (ALEXNET_STACK, ConvLayer, evaluate,
                                      plan_decomposition)
from repro.core.schedule import (WaveProgram, compile_layer,
                                 compile_network, partition_waves,
                                 validate_waves)
from repro.core.streaming import (clear_executor_cache, conv2d_direct,
                                  executor_cache_size, maxpool_direct,
                                  network_forward_fn, network_operands,
                                  run_layer_interpreted, run_layer_streamed,
                                  set_executor_cache_limit)
from repro.launch.session import StreamingSession

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # dev-only dependency (requirements.txt)
    hypothesis = None


def _layer_weights(layer, key=1, scale=0.2):
    l = layer
    return jax.random.normal(
        jax.random.key(key),
        (l.kernel, l.kernel, l.in_c // l.groups, l.out_c)) * scale


# ---------------------------------------------------------------------------
# Bit-exactness: wave executor == interpreted tile walk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layer", ALEXNET_STACK, ids=lambda l: l.name)
def test_wave_bit_identical_alexnet(layer):
    """Every ALEXNET_STACK layer under its own 128 KB plan — grouped
    conv2/4/5 and the in_splits=256 partial-sum chain of conv3 included:
    the fused wave dispatches reproduce the interpreted walk bit for
    bit (the ISSUE 2 acceptance gate)."""
    l = layer
    plan = plan_decomposition(l, 128 * 1024)
    x = jax.random.normal(jax.random.key(0), (2, l.in_h, l.in_w, l.in_c))
    w = _layer_weights(l, scale=0.05)
    b = jax.random.normal(jax.random.key(7), (l.out_c,)) * 0.1
    wave = run_layer_streamed(l, plan, x, w, b, mode="wave")
    interp = run_layer_interpreted(l, plan, x, w, b)
    assert jnp.array_equal(wave, interp), "wave executor != tile loop"
    scan = run_layer_streamed(l, plan, x, w, b, mode="jit")
    assert jnp.array_equal(wave, scan), "wave executor != scan executor"


@pytest.mark.parametrize("th,tw,fs,cs", [(1, 1, 1, 1), (3, 2, 2, 1),
                                         (2, 2, 1, 2), (2, 3, 4, 4),
                                         (2, 2, 3, 8)])
def test_wave_matches_interpreter_synthetic_plans(th, tw, fs, cs):
    """Partial-sum chains (cs > 1) and ragged feature splits."""
    layer = ConvLayer("t", 21, 17, 8, 12, 3, stride=2, pad=1)
    plan = evaluate(layer, th, tw, fs, cs)
    assert plan is not None
    x = jax.random.normal(jax.random.key(3), (1, 21, 17, 8))
    w = _layer_weights(layer)
    wave = run_layer_streamed(layer, plan, x, w, mode="wave")
    interp = run_layer_interpreted(layer, plan, x, w)
    assert jnp.array_equal(wave, interp)
    assert jnp.max(jnp.abs(wave - conv2d_direct(x, w, 2, 1))) < 1e-4


def test_wave_with_pallas_backend():
    """The wave dispatch hands its stacked (T*B, ih, iw, c) batch to the
    pluggable conv backend — Pallas conv_stream included."""
    layer = ConvLayer("pk", 16, 16, 4, 8, 3, stride=1, pad=0)
    plan = evaluate(layer, 2, 2, 2, 1)
    x = jax.random.normal(jax.random.key(0), (1, 16, 16, 4))
    w = jax.random.normal(jax.random.key(1), (3, 3, 4, 8)) * 0.2
    got = run_layer_streamed(layer, plan, x, w, mode="wave",
                             conv_backend="pallas")
    ref = conv2d_direct(x, w, 1, 0)
    assert jnp.max(jnp.abs(got - ref)) < 1e-4


def test_wave_rejects_unknown_mode():
    layer = ConvLayer("m", 8, 8, 3, 4, 3)
    plan = evaluate(layer, 1, 1, 1, 1)
    x = jnp.zeros((1, 8, 8, 3))
    with pytest.raises(ValueError, match="unknown executor mode"):
        run_layer_streamed(layer, plan, x, _layer_weights(layer),
                           mode="warp")


# ---------------------------------------------------------------------------
# Partition safety: no wave co-schedules two writers of one output block
# ---------------------------------------------------------------------------

def _assert_wave_invariants(wprog: WaveProgram):
    seen_chain: dict = {}
    for k, wave in enumerate(wprog.waves):
        blocks = [(s[2], s[3], s[6]) for s in wave]
        # independence: distinct output blocks within a wave
        assert len(set(blocks)) == len(blocks), (
            f"wave {k} co-schedules two writers of one output block")
        # chain order: wave index == position in the block's psum chain
        for blk in blocks:
            assert seen_chain.get(blk, 0) == k
            seen_chain[blk] = k + 1
    # completeness: every program step landed in exactly one wave
    assert sum(len(w) for w in wprog.waves) == wprog.program.n_steps


def test_wave_partition_property_sweep():
    """Deterministic sweep over the planner's whole (tiles, feat, in)
    grid for representative geometries — runs even without hypothesis."""
    layers = [
        ConvLayer("s1", 21, 17, 8, 12, 3, stride=2, pad=1),
        ConvLayer("s2", 27, 27, 96, 256, 5, pad=2, groups=2),
        ConvLayer("s3", 13, 13, 16, 24, 3, pad=1),
    ]
    checked = 0
    for layer in layers:
        for th in (1, 2, 3):
            for tw in (1, 2, 4):
                for fs in (1, 2, 4, 8):
                    for cs in (1, 2, 4):
                        plan = evaluate(layer, th, tw, fs, cs)
                        if plan is None:
                            continue
                        wprog = partition_waves(
                            compile_layer(layer, plan))
                        _assert_wave_invariants(wprog)
                        checked += 1
    assert checked > 30  # the sweep actually exercised the grid


@pytest.mark.parametrize("layer", ALEXNET_STACK, ids=lambda l: l.name)
def test_wave_partition_alexnet_plans(layer):
    plan = plan_decomposition(layer, 128 * 1024)
    wprog = partition_waves(compile_layer(layer, plan))
    _assert_wave_invariants(wprog)
    expected_waves = plan.in_splits if layer.groups == 1 else 1
    assert wprog.n_waves == expected_waves


def test_validate_waves_rejects_duplicate_block():
    """A corrupted wave (two writers of one block) must not validate."""
    layer = ConvLayer("v", 8, 8, 4, 8, 3, pad=1)
    plan = evaluate(layer, 2, 1, 1, 1)
    wprog = partition_waves(compile_layer(layer, plan))
    bad = wprog.waves[0][:1] + wprog.waves[0][:1]  # same block twice
    import dataclasses
    corrupted = dataclasses.replace(wprog, waves=(bad,))
    with pytest.raises(ValueError, match="written twice|raster"):
        validate_waves(corrupted)


# ---------------------------------------------------------------------------
# Whole-network wave path + fused conv+pool backend
# ---------------------------------------------------------------------------

def _small_net():
    layers = (ConvLayer("a", 16, 16, 3, 8, 3, pad=1, pool=2),
              ConvLayer("b", 8, 8, 8, 16, 3, pad=1))
    weights = []
    for i, l in enumerate(layers):
        w = jax.random.normal(jax.random.key(i), (l.kernel, l.kernel,
                                                  l.in_c, l.out_c)) * 0.2
        weights.append((w, jnp.zeros((l.out_c,))))
    return layers, weights


def _direct_net(layers, weights, x):
    y = x
    for l, (w, b) in zip(layers, weights):
        y = jnp.maximum(conv2d_direct(y, w, l.stride, l.pad,
                                      groups=l.groups) + b, 0)
        if l.pool > 1:
            y = maxpool_direct(y, l.pool, l.pool_stride or l.pool)
    return y


def test_network_forward_wave_equals_scan():
    layers, weights = _small_net()
    plans = [plan_decomposition(l, 64 * 1024) for l in layers]
    programs = compile_network(layers, plans)
    x = jax.random.normal(jax.random.key(5), (3, 16, 16, 3))
    outs = {}
    for mode in ("wave", "scan"):
        fwd = jax.jit(network_forward_fn(programs, mode=mode))
        outs[mode] = fwd(x, weights, network_operands(programs, mode))
    assert jnp.array_equal(outs["wave"], outs["scan"])
    assert jnp.max(jnp.abs(outs["wave"]
                           - _direct_net(layers, weights, x))) < 1e-4


def test_network_forward_fused_pool_backend():
    """pool layers routed through the fused Pallas conv+ReLU+pool kernel
    never materialise the pre-pool activation in the XLA graph."""
    layers, weights = _small_net()
    plans = [plan_decomposition(l, 64 * 1024) for l in layers]
    programs = compile_network(layers, plans)
    x = jax.random.normal(jax.random.key(6), (2, 16, 16, 3))
    fwd = jax.jit(network_forward_fn(programs, mode="wave",
                                     pool_backend="fused"))
    got = fwd(x, weights, network_operands(programs, "wave"))
    assert jnp.max(jnp.abs(got - _direct_net(layers, weights, x))) < 1e-4


def test_network_forward_rejects_bad_modes():
    layers, weights = _small_net()
    plans = [plan_decomposition(l, 64 * 1024) for l in layers]
    programs = compile_network(layers, plans)
    with pytest.raises(ValueError, match="unknown executor mode"):
        network_forward_fn(programs, mode="turbo")
    with pytest.raises(ValueError, match="no interpret mode"):
        network_forward_fn(programs, mode="interpret")
    with pytest.raises(ValueError, match="unknown pool backend"):
        network_forward_fn(programs, pool_backend="cudnn")
    with pytest.raises(ValueError, match="unknown executor mode"):
        network_operands(programs, mode="waves")
    # "jit" and "scan" are aliases at every level
    x = jnp.zeros((1, 16, 16, 3))
    a = jax.jit(network_forward_fn(programs, mode="jit"))(
        x, weights, network_operands(programs, "jit"))
    bq = jax.jit(network_forward_fn(programs, mode="scan"))(
        x, weights, network_operands(programs, "scan"))
    assert jnp.array_equal(a, bq)


def test_session_wave_mode_serves_alexnet_pool_layers():
    """Grouped pool layers (conv2/conv5, overlapping 3/2 pools) through
    the default wave session AND the fused pool backend."""
    stack = ALEXNET_STACK[:2]      # conv1 (pool 3/2) + conv2 (grouped)
    weights = [(_layer_weights(l, key=i, scale=0.05),
                jnp.zeros((l.out_c,))) for i, l in enumerate(stack)]
    x = jax.random.normal(jax.random.key(0), (2, 227, 227, 3))
    ref = _direct_net(stack, weights, x)
    sess = StreamingSession.for_network(stack, weights, max_batch=2)
    assert sess.mode == "wave"
    y = sess.run_batch(x)
    assert jnp.max(jnp.abs(y - ref)) < 1e-3
    fused = StreamingSession.for_network(stack, weights, max_batch=2,
                                         pool_backend="fused")
    yf = fused.run_batch(x)
    assert jnp.max(jnp.abs(yf - ref)) < 1e-3


def test_session_wave_microbatch_queue():
    layers, weights = _small_net()
    sess = StreamingSession.for_network(layers, weights,
                                        sram_budget=64 * 1024,
                                        max_batch=4, mode="wave")
    imgs = jax.random.normal(jax.random.key(8), (6, 16, 16, 3))
    tickets = [sess.submit(imgs[i]) for i in range(6)]
    outs = [sess.result(t) for t in tickets]
    assert sess.compile_count == 1
    ref = _direct_net(layers, weights, imgs)
    for i, o in enumerate(outs):
        assert jnp.max(jnp.abs(o - ref[i])) < 1e-4


# ---------------------------------------------------------------------------
# Executor cache hygiene (satellite: no id() reuse, bounded growth)
# ---------------------------------------------------------------------------

def test_executor_cache_clear_and_named_conv_fn():
    layer = ConvLayer("c", 12, 12, 4, 8, 3, pad=1)
    plan = evaluate(layer, 2, 1, 2, 1)
    x = jax.random.normal(jax.random.key(0), (1, 12, 12, 4))
    w = _layer_weights(layer)
    clear_executor_cache()
    assert executor_cache_size() == 0

    def my_conv(xt, wt):
        return conv2d_direct(xt, wt, 1, 0)

    for _ in range(3):  # stable callable -> one cached executable
        run_layer_streamed(layer, plan, x, w, conv_fn=my_conv, mode="wave")
    assert executor_cache_size() == 1
    # same name -> same executable even for a *different* callable
    run_layer_streamed(layer, plan, x, w, mode="wave",
                       conv_fn=lambda xt, wt: conv2d_direct(xt, wt, 1, 0),
                       conv_fn_name="xla-equivalent")
    run_layer_streamed(layer, plan, x, w, mode="wave",
                       conv_fn=lambda xt, wt: conv2d_direct(xt, wt, 1, 0),
                       conv_fn_name="xla-equivalent")
    assert executor_cache_size() == 2
    # anonymous fresh lambdas each get their own (never-recycled) token
    run_layer_streamed(layer, plan, x, w, mode="wave",
                       conv_fn=lambda xt, wt: conv2d_direct(xt, wt, 1, 0))
    assert executor_cache_size() == 3
    clear_executor_cache()
    assert executor_cache_size() == 0


def test_executor_cache_lru_bound():
    clear_executor_cache()
    set_executor_cache_limit(2)
    try:
        layer = ConvLayer("e", 12, 12, 4, 8, 3, pad=1)
        x = jax.random.normal(jax.random.key(0), (1, 12, 12, 4))
        w = _layer_weights(layer)
        for th, tw in ((1, 1), (2, 1), (1, 2), (2, 2)):
            plan = evaluate(layer, th, tw, 1, 1)
            run_layer_streamed(layer, plan, x, w, mode="wave")
        assert executor_cache_size() <= 2
        with pytest.raises(ValueError, match=">= 1"):
            set_executor_cache_limit(0)
    finally:
        set_executor_cache_limit(64)
        clear_executor_cache()


# ---------------------------------------------------------------------------
# Property-based cases (skipped cleanly without hypothesis)
# ---------------------------------------------------------------------------

if hypothesis is not None:
    @hypothesis.given(
        st.integers(6, 24), st.integers(6, 24),
        st.integers(1, 8), st.integers(1, 12),
        st.sampled_from([1, 3, 5]), st.sampled_from([1, 2]),
        st.integers(0, 2),
        st.integers(1, 3), st.integers(1, 3),
        st.sampled_from([1, 2, 3]), st.sampled_from([1, 2, 4]),
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_wave_partition_property_random(h, w, cin, cout, k, stride,
                                            pad, th, tw, fs, cs):
        layer = ConvLayer("t", h, w, cin, cout, k, stride=stride, pad=pad)
        if layer.out_h <= 0 or layer.out_w <= 0:
            return
        plan = evaluate(layer, th, tw, fs, cs)
        if plan is None:
            return
        wprog = partition_waves(compile_layer(layer, plan))
        _assert_wave_invariants(wprog)

    @hypothesis.given(
        st.integers(8, 32), st.integers(8, 32),
        st.integers(1, 16), st.integers(1, 24),
        st.sampled_from([1, 3, 5]), st.sampled_from([1, 2]),
        st.integers(0, 2),
        st.sampled_from([8, 16, 32, 64, 128]),   # planner budget, KiB
    )
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_wave_partition_property_planner_budgets(h, w, cin, cout, k,
                                                     stride, pad,
                                                     sram_kib):
        """Whatever plan the *planner* picks under a randomized SRAM
        budget (not just hand-chosen splits or the AlexNet 128 KB
        plans) must wave-partition cleanly, including the
        wave-invariant-window invariant the hoisted gather and the
        megakernel tables rely on."""
        layer = ConvLayer("t", h, w, cin, cout, k, stride=stride, pad=pad)
        if layer.out_h <= 0 or layer.out_w <= 0:
            return
        try:
            plan = plan_decomposition(layer, sram_kib * 1024)
        except ValueError:
            return              # infeasible at this budget
        wprog = partition_waves(compile_layer(layer, plan))
        _assert_wave_invariants(wprog)
        validate_waves(wprog)
        # windows are wave-invariant: the once-per-window gather holds
        for wave in wprog.tile_waves[1:]:
            assert [r[:4] for r in wave] == \
                [r[:4] for r in wprog.tile_waves[0]]
