"""xLSTM: mLSTM parallel form == recurrent form; sLSTM stability."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.models.module import init_params
from repro.models.xlstm import (_mlstm_small, apply_mlstm_block,
                                apply_slstm_block, init_mlstm_cache,
                                init_slstm_cache, mlstm_defs, mlstm_step,
                                slstm_defs, slstm_scan, _mlstm_parallel)


def _cfg():
    return dataclasses.replace(reduced_config("xlstm_125m"),
                               compute_dtype="float32")


def test_mlstm_parallel_matches_chunked():
    B, S, H, hd = 2, 64, 2, 8
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.key(0), 5)
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, H, hd))
    v = jax.random.normal(k3, (B, S, H, hd))
    logi = jax.random.normal(k4, (B, S, H))
    logf = jax.nn.log_sigmoid(jax.random.normal(k5, (B, S, H)) + 1)
    small = _mlstm_small(q, k, v, logi, logf)
    chunked = _mlstm_parallel(q, k, v, logi, logf, chunk_q=16)
    assert jnp.max(jnp.abs(small - chunked)) < 1e-4


def test_mlstm_block_decode_matches_full():
    cfg = _cfg()
    p = init_params(mlstm_defs(cfg), jax.random.key(0))
    B, S = 2, 10
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
    full, _ = apply_mlstm_block(cfg, p, x)
    cache = init_mlstm_cache(cfg, B, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = apply_mlstm_block(cfg, p, x[:, t:t + 1], cache=cache)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(got - full)) < 2e-3


def test_slstm_block_decode_matches_full():
    cfg = _cfg()
    p = init_params(slstm_defs(cfg), jax.random.key(0))
    B, S = 2, 10
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
    full, _ = apply_slstm_block(cfg, p, x)
    cache = init_slstm_cache(cfg, B, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y, cache = apply_slstm_block(cfg, p, x[:, t:t + 1], cache=cache)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(got - full)) < 1e-4


def test_slstm_exponential_gating_is_stabilised():
    """Large gate pre-activations must not overflow (m-state trick)."""
    cfg = _cfg()
    p = init_params(slstm_defs(cfg), jax.random.key(0))
    x = 50.0 * jax.random.normal(jax.random.key(1), (1, 20, cfg.d_model))
    y, _ = slstm_scan(p, x)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_mlstm_state_magnitude_bounded():
    cfg = _cfg()
    p = init_params(mlstm_defs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 64, cfg.d_model))
    _, cache = apply_mlstm_block(cfg, p, x)
    assert bool(jnp.all(jnp.isfinite(cache["C"])))
    assert bool(jnp.all(jnp.isfinite(cache["m"])))
